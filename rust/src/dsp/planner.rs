//! Planned FFT execution: a general plan compiler for the sim backend.
//!
//! `fft_stockham` (the numerical oracle in `dsp::fft`) recomputes every
//! twiddle with `sin`/`cos` per butterfly column per stage, allocates two
//! fresh `Vec<C64>` per transform, and only handles powers of two. An
//! [`FftPlan`] hoists all of that out of the row loop, exactly the way
//! cuFFT plans do, and serves **every** length:
//!
//!   * mixed-radix Stockham decomposition with radix-2/3/4/5/8 butterflies
//!     and per-stage twiddle tables, precomputed once per transform length
//!     and cached process-wide ([`plan_for`]) — only the **forward** tables
//!     are stored; the inverse direction conjugates them at execution
//!     time. The compiler prefers radix 8, then 4, over pairs of 2s, so a
//!     2^k length runs in ⌈k/3⌉ passes instead of k (each pass streams the
//!     whole plane, so fewer passes is proportionally less memory
//!     traffic). The radix-2-first schedule survives as
//!     [`FftPlan::new_radix2`], the bit-identity oracle against
//!     `fft_stockham`,
//!   * **native-precision kernels**: every pass is monomorphized over
//!     [`PlanScalar`], so f32 batches execute in f32 planes end-to-end
//!     (twiddles pre-narrowed to f32 at plan build) and f64 batches in f64
//!     planes — no up-conversion, half the memory traffic on the dominant
//!     f32 serving workload,
//!   * **row-blocked batch-major execution**: a block of rows is
//!     transposed into batch-major SoA planes (element `(row r, col c)` at
//!     `c·bl + r`), which fuses each butterfly group's column and row
//!     loops into one contiguous span with a constant twiddle — the inner
//!     loop is a pure FMA stream over `stride·bl` adjacent elements, which
//!     auto-vectorizes. The block size is chosen for L2 residency
//!     (`FFTSWEEP_FFT_BLOCK` overrides); block = 1 degenerates to the
//!     exact per-row loop, so f64 pow2 output stays bit-identical to the
//!     oracle at any block size (per-element operation order never
//!     changes),
//!   * a cache-blocked **four-step** decomposition for large smooth N
//!     ([`PlanAlgorithm::FourStep`]): N = N1·N2 runs as N1 row transforms
//!     of length N2, an O(N) inter-step twiddle sweep, a blocked
//!     transpose, and N2 row transforms of length N1 — each sub-plan is
//!     small enough to stay L2-resident through the row-blocked
//!     batch-major path, so no butterfly pass ever streams the full plane
//!     from DRAM. Selected automatically once N exceeds the `row_block`
//!     L2 budget (`FFTSWEEP_FFT_FOURSTEP` overrides the threshold),
//!   * Bluestein's chirp-z algorithm as the fallback for lengths with
//!     prime factors other than 2/3/5 — executed in f64 planes regardless
//!     of the I/O precision (the quadratic chirp phase wants the headroom;
//!     this is the documented precision-tier exception),
//!   * an FFT-domain convolution plan ([`ConvPlan`]): batched overlap-save
//!     FIR filtering reusing the Bluestein forward→pointwise→inverse
//!     machinery for user-supplied kernels — the kernel spectrum is
//!     computed once per (N, kernel) and cached ([`conv_plan_for`]), and
//!     the per-block pointwise multiply runs in native precision,
//!   * a real-input path ([`RfftPlan`]): an even-N real transform packs
//!     into an N/2 complex transform plus an O(N) unpack (row-blocked and
//!     native-precision when the half plan is mixed radix); odd N falls
//!     back to the complex plan with a zero imaginary plane,
//!   * batch execution through a **persistent worker pool**
//!     ([`run_rows`], [`run_rfft_rows`]): parked idle threads sized by
//!     cores / `FFTSWEEP_FFT_THREADS`, a row-range work queue, zero thread
//!     spawns after pool initialization, and the same `PAR_MIN_ELEMS`
//!     serial cutoff as before. Rows are independent and each runs the
//!     identical per-row code, so pool output is bit-identical to serial
//!     at equal precision.

use std::collections::HashMap;
use std::sync::{Arc, Mutex, OnceLock};

use crate::dsp::fft::C64;
use crate::util::workpool::{PoolStats, WorkPool};

/// Transform direction. `Forward` matches `dsp::fft` (sign −1);
/// `Inverse` is the unnormalized adjoint (sign +1) — callers scale by
/// 1/N themselves, as with `fft_stockham(x, 1.0)`. The inverse direction
/// carries no tables of its own: it conjugates the forward twiddles at
/// execution time.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Direction {
    Forward,
    Inverse,
}

/// Sample type a plan executes on **natively**: the butterfly kernels are
/// monomorphized over this trait, so `f32` rows run in f32 planes with
/// pre-narrowed f32 twiddles and `f64` rows in f64 planes. Implemented
/// for `f32` and `f64` only.
pub trait PlanScalar:
    Copy
    + Send
    + Sync
    + PartialEq
    + std::fmt::Debug
    + 'static
    + std::ops::Add<Output = Self>
    + std::ops::Sub<Output = Self>
    + std::ops::Mul<Output = Self>
    + std::ops::Neg<Output = Self>
{
    const ZERO: Self;
    fn from_f64(x: f64) -> Self;
    fn to_f64(self) -> f64;
    /// This precision's pre-narrowed view of a twiddle table.
    fn tw(table: &TwiddleTable) -> (&[Self], &[Self]);
    /// This precision's planes inside the shared scratch.
    fn planes_mut(s: &mut FftScratch) -> &mut PrecisionScratch<Self>;
    fn planes_ref(s: &FftScratch) -> &PrecisionScratch<Self>;
}

impl PlanScalar for f32 {
    const ZERO: Self = 0.0;
    #[inline]
    fn from_f64(x: f64) -> Self {
        x as f32
    }
    #[inline]
    fn to_f64(self) -> f64 {
        self as f64
    }
    #[inline]
    fn tw(table: &TwiddleTable) -> (&[Self], &[Self]) {
        (&table.re32, &table.im32)
    }
    #[inline]
    fn planes_mut(s: &mut FftScratch) -> &mut PrecisionScratch<Self> {
        &mut s.s32
    }
    #[inline]
    fn planes_ref(s: &FftScratch) -> &PrecisionScratch<Self> {
        &s.s32
    }
}

impl PlanScalar for f64 {
    const ZERO: Self = 0.0;
    #[inline]
    fn from_f64(x: f64) -> Self {
        x
    }
    #[inline]
    fn to_f64(self) -> f64 {
        self
    }
    #[inline]
    fn tw(table: &TwiddleTable) -> (&[Self], &[Self]) {
        (&table.re64, &table.im64)
    }
    #[inline]
    fn planes_mut(s: &mut FftScratch) -> &mut PrecisionScratch<Self> {
        &mut s.s64
    }
    #[inline]
    fn planes_ref(s: &FftScratch) -> &PrecisionScratch<Self> {
        &s.s64
    }
}

/// Which decomposition a plan compiled to (exposed for tests, docs and
/// the pricing layer's sanity checks).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PlanAlgorithm {
    /// Stockham mixed-radix (every prime factor in {2, 3, 5}).
    MixedRadix,
    /// Cache-blocked four-step decomposition (large smooth N = N1·N2).
    FourStep,
    /// Chirp-z convolution through a padded power-of-two plan.
    Bluestein,
}

/// Whether every prime factor of `n` is in {2, 3, 5} (the lengths the
/// Stockham stage compiler handles directly).
fn is_smooth(n: usize) -> bool {
    let mut rem = n;
    for r in [2usize, 3, 5] {
        while rem % r == 0 {
            rem /= r;
        }
    }
    rem == 1
}

/// Every length >= 1 has a plan (mixed radix or the Bluestein fallback).
/// The coordinator checks this at submit time so an unplannable job is a
/// typed error instead of a worker-thread panic.
pub fn supports(n: usize) -> bool {
    n >= 1
}

/// One direction's twiddle constants, stored in f64 and pre-narrowed to
/// f32 at build time so each precision's kernel loads its native width.
/// Only the forward direction is stored per stage — inverse execution
/// negates the imaginary part in the kernel (exact conjugation), which
/// halves what two stored directions used to cost.
pub struct TwiddleTable {
    re64: Vec<f64>,
    im64: Vec<f64>,
    re32: Vec<f32>,
    im32: Vec<f32>,
}

impl TwiddleTable {
    fn new(re64: Vec<f64>, im64: Vec<f64>) -> Self {
        let re32 = re64.iter().map(|&v| v as f32).collect();
        let im32 = im64.iter().map(|&v| v as f32).collect();
        Self {
            re64,
            im64,
            re32,
            im32,
        }
    }

    /// Entries in the table (complex constants).
    fn entries(&self) -> usize {
        self.re64.len()
    }

    /// Bytes held: f64 re+im plus the pre-narrowed f32 re+im.
    fn bytes(&self) -> usize {
        self.entries() * (2 * std::mem::size_of::<f64>() + 2 * std::mem::size_of::<f32>())
    }
}

/// One Stockham stage: `m` butterfly groups of `radix` inputs at `stride`
/// columns each, with the `(radix-1)` forward twiddles per group
/// precomputed as `tw[p*(radix-1) + (j-1)] = expi(theta0 * p * j)`,
/// `theta0 = -2π/n_cur`.
struct Stage {
    m: usize,
    stride: usize,
    radix: usize,
    tw: TwiddleTable,
}

/// A reusable execution plan for one transform length: per-stage forward
/// twiddle tables (mixed radix; inverse derived by conjugation), or the
/// precomputed chirp / kernel-spectrum state (Bluestein). Immutable after
/// construction; share it freely across threads (the cache hands out
/// `Arc<FftPlan>`).
pub struct FftPlan {
    n: usize,
    stages: Vec<Stage>,
    bluestein: Option<Bluestein>,
    four_step: Option<FourStep>,
}

impl FftPlan {
    /// Build the plan for length `n` (any `n >= 1`). Prefer [`plan_for`],
    /// which caches plans process-wide. Smooth lengths past the four-step
    /// threshold compile to the cache-blocked decomposition; non-smooth
    /// lengths to Bluestein; everything else to a monolithic mixed-radix
    /// schedule.
    pub fn new(n: usize) -> Self {
        assert!(n >= 1, "FFT length must be >= 1");
        if !is_smooth(n) {
            return Self {
                n,
                stages: Vec::new(),
                bluestein: Some(Bluestein::new(n)),
                four_step: None,
            };
        }
        if n > four_step_threshold() {
            if let Some(fs) = FourStep::new(n) {
                return Self {
                    n,
                    stages: Vec::new(),
                    bluestein: None,
                    four_step: Some(fs),
                };
            }
        }
        Self::new_monolithic(n)
    }

    /// Monolithic high-radix Stockham plan for a smooth length, whatever
    /// its size. The four-step selection in [`FftPlan::new`] supersedes
    /// this past the L2 budget; benches and tests build it directly to
    /// compare the two paths at equal length.
    pub fn new_monolithic(n: usize) -> Self {
        assert!(n >= 1, "FFT length must be >= 1");
        assert!(is_smooth(n), "monolithic plans need a 2/3/5-smooth length");
        Self {
            n,
            stages: Self::stages(n, true),
            bluestein: None,
            four_step: None,
        }
    }

    /// The radix-2-first schedule the plan compiler used before the
    /// high-radix kernels landed — kept as the bit-identity oracle: its
    /// power-of-two f64 output matches `fft_stockham` bit for bit, and
    /// the high-radix default is tolerance-tested against it.
    pub fn new_radix2(n: usize) -> Self {
        assert!(n >= 1, "FFT length must be >= 1");
        assert!(is_smooth(n), "radix-2 baseline needs a 2/3/5-smooth length");
        Self {
            n,
            stages: Self::stages(n, false),
            bluestein: None,
            four_step: None,
        }
    }

    /// Force the four-step decomposition regardless of the threshold
    /// (`None` when `n` is non-smooth or has no two-sided split). Tests
    /// and benches compare this against [`FftPlan::new_monolithic`];
    /// production callers rely on [`FftPlan::new`]'s automatic selection.
    pub fn new_four_step(n: usize) -> Option<Self> {
        if n < 1 || !is_smooth(n) {
            return None;
        }
        FourStep::new(n).map(|fs| Self {
            n,
            stages: Vec::new(),
            bluestein: None,
            four_step: Some(fs),
        })
    }

    /// Forward-direction stage list (sign −1, exactly `fft_stockham`'s
    /// twiddle expression). With `high_radix` the compiler takes 8 and 4
    /// before pairs of 2s — fewer passes over the plane and fewer twiddle
    /// loads per output; without it the radix-2-first order keeps the
    /// power-of-two schedule bit-identical to `fft_stockham`. Either way
    /// the total twiddle-entry count telescopes to n−1.
    fn stages(n: usize, high_radix: bool) -> Vec<Stage> {
        let mut out = Vec::new();
        let mut n_cur = n;
        let mut stride = 1usize;
        while n_cur > 1 {
            let radix = if high_radix {
                if n_cur % 8 == 0 {
                    8
                } else if n_cur % 4 == 0 {
                    4
                } else if n_cur % 2 == 0 {
                    2
                } else if n_cur % 3 == 0 {
                    3
                } else {
                    5
                }
            } else if n_cur % 2 == 0 {
                2
            } else if n_cur % 3 == 0 {
                3
            } else {
                5
            };
            debug_assert_eq!(n_cur % radix, 0, "stage radix must divide n_cur");
            let m = n_cur / radix;
            let theta0 = -2.0 * std::f64::consts::PI / n_cur as f64;
            let mut tw_re = Vec::with_capacity(m * (radix - 1));
            let mut tw_im = Vec::with_capacity(m * (radix - 1));
            for p in 0..m {
                for j in 1..radix {
                    let theta = theta0 * (p * j) as f64;
                    tw_re.push(theta.cos());
                    tw_im.push(theta.sin());
                }
            }
            out.push(Stage {
                m,
                stride,
                radix,
                tw: TwiddleTable::new(tw_re, tw_im),
            });
            n_cur = m;
            stride *= radix;
        }
        out
    }

    pub fn n(&self) -> usize {
        self.n
    }

    /// Which decomposition this plan compiled to.
    pub fn algorithm(&self) -> PlanAlgorithm {
        if self.bluestein.is_some() {
            PlanAlgorithm::Bluestein
        } else if self.four_step.is_some() {
            PlanAlgorithm::FourStep
        } else {
            PlanAlgorithm::MixedRadix
        }
    }

    /// Whether this plan takes the cache-blocked four-step path.
    pub fn is_four_step(&self) -> bool {
        self.four_step.is_some()
    }

    /// The (N1, N2) split of a four-step plan (`None` otherwise).
    pub fn four_step_split(&self) -> Option<(usize, usize)> {
        self.four_step.as_ref().map(|f| (f.n1, f.n2))
    }

    /// Stage radices of the monolithic schedule, outermost first (empty
    /// for four-step and Bluestein plans, whose butterflies live in their
    /// sub-plans).
    pub fn stage_radices(&self) -> Vec<usize> {
        self.stages.iter().map(|s| s.radix).collect()
    }

    /// Full-plane sweeps one transform executes — the memory-traffic
    /// proxy the high-radix schedule and the four-step split both lower.
    /// Monolithic: the stage count. Four-step: both sub-plans' passes
    /// plus the inter-step twiddle sweep (the transposes ride inside it).
    /// Bluestein: two inner transforms plus the three O(m) pointwise
    /// sweeps.
    pub fn pass_count(&self) -> usize {
        if let Some(b) = &self.bluestein {
            return 2 * b.inner.pass_count() + 3;
        }
        if let Some(fs) = &self.four_step {
            return fs.col.pass_count() + fs.row.pass_count() + 1;
        }
        self.stages.len()
    }

    /// Bytes of precomputed constants this plan holds (stage twiddles in
    /// both precisions, plus chirp/kernel-spectrum state for Bluestein
    /// and the split inter-step tables for four-step — sub-plans are
    /// shared through the plan cache and counted there). Only one
    /// direction is stored — the plan-size regression tests gate this so
    /// a second direction can never silently creep back in.
    pub fn twiddle_bytes(&self) -> usize {
        let stages: usize = self.stages.iter().map(|s| s.tw.bytes()).sum();
        let blue = self.bluestein.as_ref().map_or(0, |b| b.table_bytes());
        let four = self.four_step.as_ref().map_or(0, |f| f.table_bytes());
        stages + blue + four
    }

    /// Equivalent radix-2 butterfly stages one transform issues per
    /// element — the compute-side input to the roofline classifier
    /// (`analysis::roofline::classify_plan`). A radix-8 pass does the
    /// work of three radix-2 stages in a single plane sweep, so this is
    /// Σ log₂(radix) over the schedule; sub-plans recurse (Bluestein
    /// runs its inner transform twice, four-step runs its column and row
    /// schedules once each per element).
    pub fn radix2_equiv_stages(&self) -> f64 {
        if let Some(b) = &self.bluestein {
            return 2.0 * b.inner.radix2_equiv_stages();
        }
        if let Some(fs) = &self.four_step {
            return fs.col.radix2_equiv_stages() + fs.row.radix2_equiv_stages();
        }
        self.stages.iter().map(|s| (s.radix as f64).log2()).sum()
    }

    /// Total bytes one transform moves through the memory system at the
    /// given execution precision: each plane sweep reads and writes the
    /// full complex plane, plus the precomputed tables streamed
    /// alongside. Four-step charges its sub-plans per column/row
    /// transform plus the inter-step twiddle sweep; Bluestein charges
    /// two inner length-m transforms and its three O(m) pointwise sweeps
    /// at f64 (the documented accuracy tier it executes in regardless of
    /// the requested precision). This is the demand-traffic measure the
    /// roofline reports — actual DRAM traffic is lower when a plan is
    /// cache-resident, which the classifier models via its bandwidth
    /// tier, not here.
    pub fn bytes_moved(&self, precision: crate::types::Precision) -> u64 {
        let cb = precision.complex_bytes();
        if let Some(b) = &self.bluestein {
            let cb64 = crate::types::Precision::Fp64.complex_bytes();
            return 2 * b.inner.bytes_moved(crate::types::Precision::Fp64)
                + 3 * 2 * cb64 * b.m as u64
                + b.table_bytes() as u64;
        }
        if let Some(fs) = &self.four_step {
            return fs.n1 as u64 * fs.col.bytes_moved(precision)
                + fs.n2 as u64 * fs.row.bytes_moved(precision)
                + 2 * cb * self.n as u64
                + fs.table_bytes() as u64;
        }
        self.stages.len() as u64 * 2 * cb * self.n as u64 + self.twiddle_bytes() as u64
    }

    /// Transform a block of `bl` rows already loaded into `s`'s A planes
    /// in batch-major layout; returns `true` when the result ended in the
    /// A planes (even stage count). Mixed-radix plans only (Bluestein
    /// routes through `run_row`).
    fn run_block<T: PlanScalar>(
        &self,
        dir: Direction,
        bl: usize,
        s: &mut PrecisionScratch<T>,
    ) -> bool {
        let conj = dir == Direction::Inverse;
        let len = self.n * bl;
        let (a_re, a_im, b_re, b_im) = s.planes(len);
        let mut in_a = true;
        for st in &self.stages {
            if in_a {
                st.pass(conj, bl, a_re, a_im, b_re, b_im);
            } else {
                st.pass(conj, bl, b_re, b_im, a_re, a_im);
            }
            in_a = !in_a;
        }
        in_a
    }

    /// Transform one row: load `re_in`/`im_in` into scratch, run every
    /// stage, store into `out_re`/`out_im`. All slices must have length
    /// `self.n()`. Steady-state this performs zero heap allocation: the
    /// scratch planes are grown once and reused. Execution is native-`T`
    /// (no precision conversion) except through Bluestein plans, which
    /// compute in f64 planes.
    pub fn run_row<T: PlanScalar>(
        &self,
        dir: Direction,
        re_in: &[T],
        im_in: &[T],
        out_re: &mut [T],
        out_im: &mut [T],
        scratch: &mut FftScratch,
    ) {
        let n = self.n;
        assert_eq!(re_in.len(), n, "re input length");
        assert_eq!(im_in.len(), n, "im input length");
        assert_eq!(out_re.len(), n, "re output length");
        assert_eq!(out_im.len(), n, "im output length");
        if let Some(bl) = &self.bluestein {
            bl.run_row(dir, re_in, im_in, out_re, out_im, scratch);
            return;
        }
        if let Some(fs) = &self.four_step {
            fs.run_row(dir, re_in, im_in, out_re, out_im, scratch);
            return;
        }
        let s = T::planes_mut(scratch);
        s.ensure(n);
        {
            let (a_re, a_im, _, _) = s.planes(n);
            a_re.copy_from_slice(re_in);
            a_im.copy_from_slice(im_in);
        }
        let in_a = self.run_block::<T>(dir, 1, s);
        let (a_re, a_im, b_re, b_im) = s.planes(n);
        let (res_re, res_im): (&[T], &[T]) = if in_a { (a_re, a_im) } else { (b_re, b_im) };
        out_re.copy_from_slice(res_re);
        out_im.copy_from_slice(res_im);
    }

    /// Transform `rows` consecutive rows serially with one scratch.
    /// `re`/`im` and the outputs are row-major `rows × n`. Mixed-radix
    /// plans execute row-blocked: up to [`row_block`] rows are transposed
    /// into batch-major planes and swept together, so the butterfly inner
    /// loops stride contiguously and auto-vectorize.
    #[allow(clippy::too_many_arguments)]
    pub fn run_rows_serial<T: PlanScalar>(
        &self,
        dir: Direction,
        re: &[T],
        im: &[T],
        rows: usize,
        out_re: &mut [T],
        out_im: &mut [T],
        scratch: &mut FftScratch,
    ) {
        let n = self.n;
        assert!(re.len() >= rows * n && im.len() >= rows * n, "input planes too short");
        assert!(out_re.len() >= rows * n && out_im.len() >= rows * n, "output planes too short");
        // Bluestein and four-step plans route per-row: each row's code is
        // identical regardless of batch shape, so pool output stays
        // bit-identical to serial for them too.
        if self.bluestein.is_some() || self.four_step.is_some() {
            for r in 0..rows {
                let off = r * n;
                self.run_row(
                    dir,
                    &re[off..off + n],
                    &im[off..off + n],
                    &mut out_re[off..off + n],
                    &mut out_im[off..off + n],
                    scratch,
                );
            }
            return;
        }
        // Never grow scratch past what this batch actually needs: a small
        // batch under a large (possibly overridden) block size stays small.
        let bl_max = row_block::<T>(n).min(rows.max(1));
        let s = T::planes_mut(scratch);
        s.ensure(n * bl_max);
        let mut r0 = 0usize;
        while r0 < rows {
            let bl = bl_max.min(rows - r0);
            {
                // Load transpose: row-major input → batch-major planes.
                let (a_re, a_im, _, _) = s.planes(n * bl);
                for r in 0..bl {
                    let row_re = &re[(r0 + r) * n..][..n];
                    let row_im = &im[(r0 + r) * n..][..n];
                    for c in 0..n {
                        a_re[c * bl + r] = row_re[c];
                        a_im[c * bl + r] = row_im[c];
                    }
                }
            }
            let in_a = self.run_block::<T>(dir, bl, s);
            let (a_re, a_im, b_re, b_im) = s.planes(n * bl);
            let (res_re, res_im): (&[T], &[T]) = if in_a { (a_re, a_im) } else { (b_re, b_im) };
            for r in 0..bl {
                let out_r = &mut out_re[(r0 + r) * n..][..n];
                let out_i = &mut out_im[(r0 + r) * n..][..n];
                for c in 0..n {
                    out_r[c] = res_re[c * bl + r];
                    out_i[c] = res_im[c * bl + r];
                }
            }
            r0 += bl;
        }
    }
}

impl Stage {
    /// One Stockham pass over a batch-major block: reads `cur`, writes
    /// `nxt`. In batch-major layout a butterfly group's `stride` columns ×
    /// `bl` rows form one contiguous span of `stride·bl` elements sharing
    /// a single twiddle, so the inner loops below are pure contiguous
    /// load/multiply/add streams — no trig, no allocation, no gather.
    /// At `bl = 1` the spans and the per-element operation order are
    /// exactly the pre-block per-row kernels (f64 pow2 stays bit-identical
    /// to `fft_stockham`). `conj` selects the inverse direction by
    /// negating the twiddle imaginary parts (exact conjugation).
    #[inline]
    fn pass<T: PlanScalar>(
        &self,
        conj: bool,
        bl: usize,
        cur_re: &[T],
        cur_im: &[T],
        nxt_re: &mut [T],
        nxt_im: &mut [T],
    ) {
        match self.radix {
            2 => self.pass_r2(conj, bl, cur_re, cur_im, nxt_re, nxt_im),
            3 => self.pass_r3(conj, bl, cur_re, cur_im, nxt_re, nxt_im),
            4 => self.pass_r4(conj, bl, cur_re, cur_im, nxt_re, nxt_im),
            8 => self.pass_r8(conj, bl, cur_re, cur_im, nxt_re, nxt_im),
            _ => self.pass_r5(conj, bl, cur_re, cur_im, nxt_re, nxt_im),
        }
    }

    /// Radix-2 butterfly — per-element operation order identical to
    /// `fft_stockham`, so power-of-two f64 plans stay bit-identical to
    /// the oracle.
    #[inline]
    fn pass_r2<T: PlanScalar>(
        &self,
        conj: bool,
        bl: usize,
        cur_re: &[T],
        cur_im: &[T],
        nxt_re: &mut [T],
        nxt_im: &mut [T],
    ) {
        let (tw_re, tw_im) = T::tw(&self.tw);
        let span = self.stride * bl;
        let m = self.m;
        for p in 0..m {
            let wr = tw_re[p];
            let wi = if conj { -tw_im[p] } else { tw_im[p] };
            let a_re = &cur_re[p * span..][..span];
            let a_im = &cur_im[p * span..][..span];
            let b_re = &cur_re[(p + m) * span..][..span];
            let b_im = &cur_im[(p + m) * span..][..span];
            let (o0_re, o1_re) = nxt_re[2 * p * span..][..2 * span].split_at_mut(span);
            let (o0_im, o1_im) = nxt_im[2 * p * span..][..2 * span].split_at_mut(span);
            for i in 0..span {
                let ar = a_re[i];
                let ai = a_im[i];
                let br = b_re[i];
                let bi = b_im[i];
                o0_re[i] = ar + br;
                o0_im[i] = ai + bi;
                let dr = ar - br;
                let di = ai - bi;
                o1_re[i] = dr * wr - di * wi;
                o1_im[i] = dr * wi + di * wr;
            }
        }
    }

    /// Radix-3 butterfly: y0 = a+s, y1/y2 = a - s/2 ± i·s3·d with
    /// s = b+c, d = b−c and s3 the sign-folded sqrt(3)/2.
    #[inline]
    fn pass_r3<T: PlanScalar>(
        &self,
        conj: bool,
        bl: usize,
        cur_re: &[T],
        cur_im: &[T],
        nxt_re: &mut [T],
        nxt_im: &mut [T],
    ) {
        let (tw_re, tw_im) = T::tw(&self.tw);
        // Forward sign is −1 (as the stored tables); inverse flips it.
        let sign = if conj { 1.0 } else { -1.0 };
        let s3 = T::from_f64(sign * (3.0f64.sqrt() / 2.0));
        let half = T::from_f64(0.5);
        let span = self.stride * bl;
        let m = self.m;
        for p in 0..m {
            let w1r = tw_re[2 * p];
            let w1i = if conj { -tw_im[2 * p] } else { tw_im[2 * p] };
            let w2r = tw_re[2 * p + 1];
            let w2i = if conj { -tw_im[2 * p + 1] } else { tw_im[2 * p + 1] };
            let a_re = &cur_re[p * span..][..span];
            let a_im = &cur_im[p * span..][..span];
            let b_re = &cur_re[(p + m) * span..][..span];
            let b_im = &cur_im[(p + m) * span..][..span];
            let c_re = &cur_re[(p + 2 * m) * span..][..span];
            let c_im = &cur_im[(p + 2 * m) * span..][..span];
            let (o0_re, rest_re) = nxt_re[3 * p * span..][..3 * span].split_at_mut(span);
            let (o1_re, o2_re) = rest_re.split_at_mut(span);
            let (o0_im, rest_im) = nxt_im[3 * p * span..][..3 * span].split_at_mut(span);
            let (o1_im, o2_im) = rest_im.split_at_mut(span);
            for i in 0..span {
                let ar = a_re[i];
                let ai = a_im[i];
                let br = b_re[i];
                let bi = b_im[i];
                let cr = c_re[i];
                let ci = c_im[i];
                let sr = br + cr;
                let si = bi + ci;
                let dr = br - cr;
                let di = bi - ci;
                o0_re[i] = ar + sr;
                o0_im[i] = ai + si;
                let er = ar - half * sr;
                let ei = ai - half * si;
                let fr = s3 * di;
                let fi = s3 * dr;
                let y1r = er - fr;
                let y1i = ei + fi;
                let y2r = er + fr;
                let y2i = ei - fi;
                o1_re[i] = y1r * w1r - y1i * w1i;
                o1_im[i] = y1r * w1i + y1i * w1r;
                o2_re[i] = y2r * w2r - y2i * w2i;
                o2_im[i] = y2r * w2i + y2i * w2r;
            }
        }
    }

    /// Radix-4 butterfly: one pass does the work of two radix-2 passes
    /// with a single twiddle load per output. With t0/t1 = a0±a2 and
    /// t2/t3 = a1±a3, y0 = t0+t2, y2 = t0−t2, y1/y3 = t1 ± s·i·t3 (s the
    /// direction sign, −1 forward), then the three group twiddles.
    #[inline]
    fn pass_r4<T: PlanScalar>(
        &self,
        conj: bool,
        bl: usize,
        cur_re: &[T],
        cur_im: &[T],
        nxt_re: &mut [T],
        nxt_im: &mut [T],
    ) {
        let (tw_re, tw_im) = T::tw(&self.tw);
        // Forward sign is −1 (matching the stored tables); inverse flips it.
        let sign = T::from_f64(if conj { 1.0 } else { -1.0 });
        let span = self.stride * bl;
        let m = self.m;
        for p in 0..m {
            let t = 3 * p;
            let w1r = tw_re[t];
            let w1i = if conj { -tw_im[t] } else { tw_im[t] };
            let w2r = tw_re[t + 1];
            let w2i = if conj { -tw_im[t + 1] } else { tw_im[t + 1] };
            let w3r = tw_re[t + 2];
            let w3i = if conj { -tw_im[t + 2] } else { tw_im[t + 2] };
            let a0_re = &cur_re[p * span..][..span];
            let a0_im = &cur_im[p * span..][..span];
            let a1_re = &cur_re[(p + m) * span..][..span];
            let a1_im = &cur_im[(p + m) * span..][..span];
            let a2_re = &cur_re[(p + 2 * m) * span..][..span];
            let a2_im = &cur_im[(p + 2 * m) * span..][..span];
            let a3_re = &cur_re[(p + 3 * m) * span..][..span];
            let a3_im = &cur_im[(p + 3 * m) * span..][..span];
            let (o0_re, rest_re) = nxt_re[4 * p * span..][..4 * span].split_at_mut(span);
            let (o1_re, rest_re) = rest_re.split_at_mut(span);
            let (o2_re, o3_re) = rest_re.split_at_mut(span);
            let (o0_im, rest_im) = nxt_im[4 * p * span..][..4 * span].split_at_mut(span);
            let (o1_im, rest_im) = rest_im.split_at_mut(span);
            let (o2_im, o3_im) = rest_im.split_at_mut(span);
            for i in 0..span {
                let t0r = a0_re[i] + a2_re[i];
                let t0i = a0_im[i] + a2_im[i];
                let t1r = a0_re[i] - a2_re[i];
                let t1i = a0_im[i] - a2_im[i];
                let t2r = a1_re[i] + a3_re[i];
                let t2i = a1_im[i] + a3_im[i];
                let t3r = a1_re[i] - a3_re[i];
                let t3i = a1_im[i] - a3_im[i];
                o0_re[i] = t0r + t2r;
                o0_im[i] = t0i + t2i;
                let y1r = t1r - sign * t3i;
                let y1i = t1i + sign * t3r;
                let y2r = t0r - t2r;
                let y2i = t0i - t2i;
                let y3r = t1r + sign * t3i;
                let y3i = t1i - sign * t3r;
                o1_re[i] = y1r * w1r - y1i * w1i;
                o1_im[i] = y1r * w1i + y1i * w1r;
                o2_re[i] = y2r * w2r - y2i * w2i;
                o2_im[i] = y2r * w2i + y2i * w2r;
                o3_re[i] = y3r * w3r - y3i * w3i;
                o3_im[i] = y3r * w3i + y3i * w3r;
            }
        }
    }

    /// Radix-8 butterfly: a radix-4 pass over the even inputs, one over
    /// the odd inputs, then the odd half twisted by w8^j (w8 = the
    /// eighth root with the direction sign folded in, h = √2/2) and
    /// combined as y_j = E_j ± u_j. Replaces three radix-2 passes — and
    /// three full-plane sweeps — with one.
    #[inline]
    fn pass_r8<T: PlanScalar>(
        &self,
        conj: bool,
        bl: usize,
        cur_re: &[T],
        cur_im: &[T],
        nxt_re: &mut [T],
        nxt_im: &mut [T],
    ) {
        let (tw_re, tw_im) = T::tw(&self.tw);
        let sign = T::from_f64(if conj { 1.0 } else { -1.0 });
        let h = T::from_f64(std::f64::consts::FRAC_1_SQRT_2);
        let span = self.stride * bl;
        let m = self.m;
        for p in 0..m {
            let t = 7 * p;
            let mut w = [(T::ZERO, T::ZERO); 7];
            for (j, wj) in w.iter_mut().enumerate() {
                wj.0 = tw_re[t + j];
                wj.1 = if conj { -tw_im[t + j] } else { tw_im[t + j] };
            }
            let a_re: [&[T]; 8] = std::array::from_fn(|j| &cur_re[(p + j * m) * span..][..span]);
            let a_im: [&[T]; 8] = std::array::from_fn(|j| &cur_im[(p + j * m) * span..][..span]);
            let (o0_re, rest_re) = nxt_re[8 * p * span..][..8 * span].split_at_mut(span);
            let (o1_re, rest_re) = rest_re.split_at_mut(span);
            let (o2_re, rest_re) = rest_re.split_at_mut(span);
            let (o3_re, rest_re) = rest_re.split_at_mut(span);
            let (o4_re, rest_re) = rest_re.split_at_mut(span);
            let (o5_re, rest_re) = rest_re.split_at_mut(span);
            let (o6_re, o7_re) = rest_re.split_at_mut(span);
            let (o0_im, rest_im) = nxt_im[8 * p * span..][..8 * span].split_at_mut(span);
            let (o1_im, rest_im) = rest_im.split_at_mut(span);
            let (o2_im, rest_im) = rest_im.split_at_mut(span);
            let (o3_im, rest_im) = rest_im.split_at_mut(span);
            let (o4_im, rest_im) = rest_im.split_at_mut(span);
            let (o5_im, rest_im) = rest_im.split_at_mut(span);
            let (o6_im, o7_im) = rest_im.split_at_mut(span);
            for i in 0..span {
                // Radix-4 over the even inputs (a0, a2, a4, a6) → E0..E3.
                let et0r = a_re[0][i] + a_re[4][i];
                let et0i = a_im[0][i] + a_im[4][i];
                let et1r = a_re[0][i] - a_re[4][i];
                let et1i = a_im[0][i] - a_im[4][i];
                let et2r = a_re[2][i] + a_re[6][i];
                let et2i = a_im[2][i] + a_im[6][i];
                let et3r = a_re[2][i] - a_re[6][i];
                let et3i = a_im[2][i] - a_im[6][i];
                let e0r = et0r + et2r;
                let e0i = et0i + et2i;
                let e1r = et1r - sign * et3i;
                let e1i = et1i + sign * et3r;
                let e2r = et0r - et2r;
                let e2i = et0i - et2i;
                let e3r = et1r + sign * et3i;
                let e3i = et1i - sign * et3r;
                // Radix-4 over the odd inputs (a1, a3, a5, a7) → Q0..Q3.
                let qt0r = a_re[1][i] + a_re[5][i];
                let qt0i = a_im[1][i] + a_im[5][i];
                let qt1r = a_re[1][i] - a_re[5][i];
                let qt1i = a_im[1][i] - a_im[5][i];
                let qt2r = a_re[3][i] + a_re[7][i];
                let qt2i = a_im[3][i] + a_im[7][i];
                let qt3r = a_re[3][i] - a_re[7][i];
                let qt3i = a_im[3][i] - a_im[7][i];
                let q0r = qt0r + qt2r;
                let q0i = qt0i + qt2i;
                let q1r = qt1r - sign * qt3i;
                let q1i = qt1i + sign * qt3r;
                let q2r = qt0r - qt2r;
                let q2i = qt0i - qt2i;
                let q3r = qt1r + sign * qt3i;
                let q3i = qt1i - sign * qt3r;
                // Twist the odd half: u_j = w8^j · Q_j with
                // w8 = h·(1 + s·i), w8² = s·i, w8³ = −h·(1 − s·i).
                let u0r = q0r;
                let u0i = q0i;
                let u1r = h * (q1r - sign * q1i);
                let u1i = h * (q1i + sign * q1r);
                let u2r = -(sign * q2i);
                let u2i = sign * q2r;
                let u3r = -(h * (q3r + sign * q3i));
                let u3i = -(h * (q3i - sign * q3r));
                let y0r = e0r + u0r;
                let y0i = e0i + u0i;
                let y1r = e1r + u1r;
                let y1i = e1i + u1i;
                let y2r = e2r + u2r;
                let y2i = e2i + u2i;
                let y3r = e3r + u3r;
                let y3i = e3i + u3i;
                let y4r = e0r - u0r;
                let y4i = e0i - u0i;
                let y5r = e1r - u1r;
                let y5i = e1i - u1i;
                let y6r = e2r - u2r;
                let y6i = e2i - u2i;
                let y7r = e3r - u3r;
                let y7i = e3i - u3i;
                o0_re[i] = y0r;
                o0_im[i] = y0i;
                o1_re[i] = y1r * w[0].0 - y1i * w[0].1;
                o1_im[i] = y1r * w[0].1 + y1i * w[0].0;
                o2_re[i] = y2r * w[1].0 - y2i * w[1].1;
                o2_im[i] = y2r * w[1].1 + y2i * w[1].0;
                o3_re[i] = y3r * w[2].0 - y3i * w[2].1;
                o3_im[i] = y3r * w[2].1 + y3i * w[2].0;
                o4_re[i] = y4r * w[3].0 - y4i * w[3].1;
                o4_im[i] = y4r * w[3].1 + y4i * w[3].0;
                o5_re[i] = y5r * w[4].0 - y5i * w[4].1;
                o5_im[i] = y5r * w[4].1 + y5i * w[4].0;
                o6_re[i] = y6r * w[5].0 - y6i * w[5].1;
                o6_im[i] = y6r * w[5].1 + y6i * w[5].0;
                o7_re[i] = y7r * w[6].0 - y7i * w[6].1;
                o7_im[i] = y7r * w[6].1 + y7i * w[6].0;
            }
        }
    }

    /// Radix-5 butterfly (standard 5-point DFT factorization with
    /// t1/t2 = a1±a4-style sums and the direction sign folded into s1/s2).
    #[inline]
    fn pass_r5<T: PlanScalar>(
        &self,
        conj: bool,
        bl: usize,
        cur_re: &[T],
        cur_im: &[T],
        nxt_re: &mut [T],
        nxt_im: &mut [T],
    ) {
        let (tw_re, tw_im) = T::tw(&self.tw);
        let sign = if conj { 1.0 } else { -1.0 };
        let fifth = 2.0 * std::f64::consts::PI / 5.0;
        let c1 = T::from_f64(fifth.cos());
        let c2 = T::from_f64((2.0 * fifth).cos());
        let s1 = T::from_f64(sign * fifth.sin());
        let s2 = T::from_f64(sign * (2.0 * fifth).sin());
        let span = self.stride * bl;
        let m = self.m;
        for p in 0..m {
            let tw = 4 * p;
            let w1r = tw_re[tw];
            let w1i = if conj { -tw_im[tw] } else { tw_im[tw] };
            let w2r = tw_re[tw + 1];
            let w2i = if conj { -tw_im[tw + 1] } else { tw_im[tw + 1] };
            let w3r = tw_re[tw + 2];
            let w3i = if conj { -tw_im[tw + 2] } else { tw_im[tw + 2] };
            let w4r = tw_re[tw + 3];
            let w4i = if conj { -tw_im[tw + 3] } else { tw_im[tw + 3] };
            let a0_re = &cur_re[p * span..][..span];
            let a0_im = &cur_im[p * span..][..span];
            let a1_re = &cur_re[(p + m) * span..][..span];
            let a1_im = &cur_im[(p + m) * span..][..span];
            let a2_re = &cur_re[(p + 2 * m) * span..][..span];
            let a2_im = &cur_im[(p + 2 * m) * span..][..span];
            let a3_re = &cur_re[(p + 3 * m) * span..][..span];
            let a3_im = &cur_im[(p + 3 * m) * span..][..span];
            let a4_re = &cur_re[(p + 4 * m) * span..][..span];
            let a4_im = &cur_im[(p + 4 * m) * span..][..span];
            let (o0_re, rest_re) = nxt_re[5 * p * span..][..5 * span].split_at_mut(span);
            let (o1_re, rest_re) = rest_re.split_at_mut(span);
            let (o2_re, rest_re) = rest_re.split_at_mut(span);
            let (o3_re, o4_re) = rest_re.split_at_mut(span);
            let (o0_im, rest_im) = nxt_im[5 * p * span..][..5 * span].split_at_mut(span);
            let (o1_im, rest_im) = rest_im.split_at_mut(span);
            let (o2_im, rest_im) = rest_im.split_at_mut(span);
            let (o3_im, o4_im) = rest_im.split_at_mut(span);
            for i in 0..span {
                let a0r = a0_re[i];
                let a0i = a0_im[i];
                let a1r = a1_re[i];
                let a1i = a1_im[i];
                let a2r = a2_re[i];
                let a2i = a2_im[i];
                let a3r = a3_re[i];
                let a3i = a3_im[i];
                let a4r = a4_re[i];
                let a4i = a4_im[i];
                let t1r = a1r + a4r;
                let t1i = a1i + a4i;
                let t2r = a2r + a3r;
                let t2i = a2i + a3i;
                let t3r = a1r - a4r;
                let t3i = a1i - a4i;
                let t4r = a2r - a3r;
                let t4i = a2i - a3i;
                o0_re[i] = a0r + t1r + t2r;
                o0_im[i] = a0i + t1i + t2i;
                let m1r = a0r + c1 * t1r + c2 * t2r;
                let m1i = a0i + c1 * t1i + c2 * t2i;
                let m2r = a0r + c2 * t1r + c1 * t2r;
                let m2i = a0i + c2 * t1i + c1 * t2i;
                let u1r = s1 * t3r + s2 * t4r;
                let u1i = s1 * t3i + s2 * t4i;
                let u2r = s2 * t3r - s1 * t4r;
                let u2i = s2 * t3i - s1 * t4i;
                // y_j = m ± i·u, then the group twiddle w_j.
                let y1r = m1r - u1i;
                let y1i = m1i + u1r;
                let y2r = m2r - u2i;
                let y2i = m2i + u2r;
                let y3r = m2r + u2i;
                let y3i = m2i - u2r;
                let y4r = m1r + u1i;
                let y4i = m1i - u1r;
                o1_re[i] = y1r * w1r - y1i * w1i;
                o1_im[i] = y1r * w1i + y1i * w1r;
                o2_re[i] = y2r * w2r - y2i * w2i;
                o2_im[i] = y2r * w2i + y2i * w2r;
                o3_re[i] = y3r * w3r - y3i * w3i;
                o3_im[i] = y3r * w3i + y3i * w3r;
                o4_re[i] = y4r * w4r - y4i * w4i;
                o4_im[i] = y4r * w4i + y4i * w4r;
            }
        }
    }
}

/// Row-block size for batch-major execution: the largest block whose
/// working set (4 planes × n × block × element width) stays within a
/// half-L2 budget, clamped to [1, 32]. `FFTSWEEP_FFT_BLOCK` overrides
/// (parsed once). Block size never changes results — only the memory
/// layout the rows are swept in.
fn row_block<T: PlanScalar>(n: usize) -> usize {
    const L2_BUDGET_BYTES: usize = 256 * 1024;
    if let Some(b) = block_override() {
        // Clamped too: an experimental override must not be able to make
        // `ensure(n·block)` allocate unboundedly.
        return b.clamp(1, 256);
    }
    (L2_BUDGET_BYTES / (4 * n * std::mem::size_of::<T>()).max(1)).clamp(1, 32)
}

fn block_override() -> Option<usize> {
    static BLOCK: OnceLock<Option<usize>> = OnceLock::new();
    *BLOCK.get_or_init(|| {
        std::env::var("FFTSWEEP_FFT_BLOCK")
            .ok()
            .and_then(|v| v.trim().parse::<usize>().ok())
    })
}

/// Four-step selection threshold: smooth plans longer than this leave the
/// monolithic Stockham path for the cache-blocked decomposition. The
/// default is the length where [`row_block`]'s f32 working set (4 planes
/// × n × 4 B) exactly fills the 256 KiB half-L2 budget — past it every
/// monolithic pass streams the whole plane through DRAM.
/// `FFTSWEEP_FFT_FOURSTEP=<n>` overrides the threshold (parsed once;
/// set it very large to force monolithic plans at any length, or 0 to
/// take the four-step path everywhere it splits).
const FOUR_STEP_DEFAULT_THRESHOLD: usize = 16384;

fn four_step_threshold() -> usize {
    static T: OnceLock<usize> = OnceLock::new();
    *T.get_or_init(|| {
        std::env::var("FFTSWEEP_FFT_FOURSTEP")
            .ok()
            .and_then(|v| v.trim().parse::<usize>().ok())
            .unwrap_or(FOUR_STEP_DEFAULT_THRESHOLD)
    })
}

/// The divisor pair (n1, n2), n1 ≤ n2, of a smooth `n` with n1 nearest
/// √n — the most balanced split, which keeps both four-step sub-plans as
/// small (and as L2-resident) as possible. `None` when no two-sided
/// split exists (n < 4 or prime).
fn split_near_sqrt(n: usize) -> Option<(usize, usize)> {
    if n < 4 {
        return None;
    }
    let root = (n as f64).sqrt() as usize;
    (2..=root.max(2))
        .rev()
        .find(|d| n % d == 0)
        .map(|d| (d, n / d))
}

/// Granularity of the split four-step twiddle factorization: the flat
/// table `w[idx] = expi(−2π·idx/N)` would cost O(N) complex entries
/// (~100 MB at 2²²), so it is factored exactly as
/// `w[idx] = hi[idx / 256] · lo[idx % 256]` — O(N/256 + 256) entries and
/// one extra complex multiply per element (angles add, so the product is
/// exact up to one rounding).
const FOURSTEP_TW_LO: usize = 256;

/// Cache-blocked four-step (Bailey) decomposition state for one large
/// smooth length N = N1·N2. With t = t1 + N1·t2 and k = k2 + N2·k1:
///
///   `X[k2 + N2·k1] = Σ_{t1} w^{t1·k2} · e_{N1}^{t1·k1} ·
///                    (Σ_{t2} x[t1 + N1·t2] · e_{N2}^{t2·k2})`
///
/// executed as: gather-transpose into an N1×N2 matrix → N1 row FFTs of
/// length N2 → O(N) inter-step twiddle `w^{t1·k2}` → blocked transpose →
/// N2 row FFTs of length N1 → transposed store. The row FFTs go through
/// the sub-plans' row-blocked batch-major path, so every butterfly sweep
/// is L2-resident; the transposes move each element once per step
/// through cache-sized tiles.
struct FourStep {
    n1: usize,
    n2: usize,
    /// Length-N2 sub-plan (the N1 "column" transforms, run as rows of
    /// the gathered matrix). Shared through the plan cache.
    col: Arc<FftPlan>,
    /// Length-N1 sub-plan (the N2 transforms after the transpose).
    row: Arc<FftPlan>,
    /// Split inter-step twiddles (see [`FOURSTEP_TW_LO`]): only the
    /// forward direction is stored; inverse execution conjugates the
    /// recombined factor.
    tw_hi: TwiddleTable,
    tw_lo: TwiddleTable,
}

impl FourStep {
    fn new(n: usize) -> Option<Self> {
        let (n1, n2) = split_near_sqrt(n)?;
        // Sub-plans go through the cache (shared with direct users of
        // those lengths) and are near √n, so recursion strictly
        // decreases; plan_for builds outside its lock, so no deadlock.
        let col = plan_for(n2);
        let row = plan_for(n1);
        let theta0 = -2.0 * std::f64::consts::PI / n as f64;
        let lo_len = FOURSTEP_TW_LO.min(n);
        let mut lo_re = Vec::with_capacity(lo_len);
        let mut lo_im = Vec::with_capacity(lo_len);
        for r in 0..lo_len {
            let theta = theta0 * r as f64;
            lo_re.push(theta.cos());
            lo_im.push(theta.sin());
        }
        let hi_len = (n - 1) / FOURSTEP_TW_LO + 1;
        let mut hi_re = Vec::with_capacity(hi_len);
        let mut hi_im = Vec::with_capacity(hi_len);
        for j in 0..hi_len {
            let theta = theta0 * (j * FOURSTEP_TW_LO) as f64;
            hi_re.push(theta.cos());
            hi_im.push(theta.sin());
        }
        Some(Self {
            n1,
            n2,
            col,
            row,
            tw_hi: TwiddleTable::new(hi_re, hi_im),
            tw_lo: TwiddleTable::new(lo_re, lo_im),
        })
    }

    /// Bytes of precomputed state (the split twiddle tables; the
    /// sub-plans are shared through the plan cache and counted there).
    fn table_bytes(&self) -> usize {
        self.tw_hi.bytes() + self.tw_lo.bytes()
    }

    fn run_row<T: PlanScalar>(
        &self,
        dir: Direction,
        re_in: &[T],
        im_in: &[T],
        out_re: &mut [T],
        out_im: &mut [T],
        scratch: &mut FftScratch,
    ) {
        let (n1, n2) = (self.n1, self.n2);
        let n = n1 * n2;
        let conj = dir == Direction::Inverse;
        let (hi_re, hi_im) = T::tw(&self.tw_hi);
        let (lo_re, lo_im) = T::tw(&self.tw_lo);
        // Take the four-step bank by value so the sub-plan rows can
        // borrow the scratch again (a Vec move, no copy; put back below).
        // This bank is dedicated — the rFFT `pack` and Bluestein `conv`
        // banks stay free for plans nesting around this one.
        let mut bank = std::mem::take(&mut T::planes_mut(scratch).fourstep);
        bank.ensure(n);
        // Step 1: gather-transpose x[t1 + N1·t2] → B[t1·N2 + t2].
        transpose_tiled(re_in, &mut bank.xr[..n], n2, n1);
        transpose_tiled(im_in, &mut bank.xi[..n], n2, n1);
        // Step 2: N1 row transforms of length N2 (row-blocked, L2-sized).
        self.col.run_rows_serial(
            dir,
            &bank.xr[..n],
            &bank.xi[..n],
            n1,
            &mut bank.yr[..n],
            &mut bank.yi[..n],
            scratch,
        );
        // Step 3: inter-step twiddle B[t1][k2] *= w^(t1·k2 mod N). The
        // index steps by t1 per column, so one conditional subtract
        // replaces the mod; t1 = 0 is the identity row and is skipped.
        for t1 in 1..n1 {
            let row_re = &mut bank.yr[t1 * n2..][..n2];
            let row_im = &mut bank.yi[t1 * n2..][..n2];
            let mut idx = 0usize;
            for k2 in 0..n2 {
                let hr = hi_re[idx / FOURSTEP_TW_LO];
                let hi_ = hi_im[idx / FOURSTEP_TW_LO];
                let lr = lo_re[idx % FOURSTEP_TW_LO];
                let li = lo_im[idx % FOURSTEP_TW_LO];
                let wr = hr * lr - hi_ * li;
                let wi_f = hr * li + hi_ * lr;
                let wi = if conj { -wi_f } else { wi_f };
                let xr = row_re[k2];
                let xi = row_im[k2];
                row_re[k2] = xr * wr - xi * wi;
                row_im[k2] = xr * wi + xi * wr;
                idx += t1;
                if idx >= n {
                    idx -= n;
                }
            }
        }
        // Step 4: blocked transpose B (N1×N2) → C (N2×N1).
        transpose_tiled(&bank.yr[..n], &mut bank.xr[..n], n1, n2);
        transpose_tiled(&bank.yi[..n], &mut bank.xi[..n], n1, n2);
        // Step 5: N2 row transforms of length N1.
        self.row.run_rows_serial(
            dir,
            &bank.xr[..n],
            &bank.xi[..n],
            n2,
            &mut bank.yr[..n],
            &mut bank.yi[..n],
            scratch,
        );
        // Step 6: transposed store out[k2 + N2·k1] = C[k2·N1 + k1].
        transpose_tiled(&bank.yr[..n], out_re, n2, n1);
        transpose_tiled(&bank.yi[..n], out_im, n2, n1);
        T::planes_mut(scratch).fourstep = bank;
    }
}

/// Cache-tiled out-of-place transpose of a `rows × cols` row-major
/// matrix: `dst[c·rows + r] = src[r·cols + c]`. Tiling keeps both the
/// read and write streams within a few cache lines per tile instead of
/// striding the full matrix height per element.
fn transpose_tiled<T: Copy>(src: &[T], dst: &mut [T], rows: usize, cols: usize) {
    const TILE: usize = 32;
    for r0 in (0..rows).step_by(TILE) {
        let r_end = (r0 + TILE).min(rows);
        for c0 in (0..cols).step_by(TILE) {
            let c_end = (c0 + TILE).min(cols);
            for r in r0..r_end {
                for c in c0..c_end {
                    dst[c * rows + r] = src[r * cols + c];
                }
            }
        }
    }
}

/// Bluestein chirp-z state: the length-N DFT expressed as a circular
/// convolution of padded power-of-two length `m >= 2N-1`, using the
/// identity `kt = (k² + t² − (k−t)²) / 2`:
///
///   `X[k] = chirp[k] · Σ_t (x[t]·chirp[t]) · c[k−t]`,
///   `chirp[k] = expi(sign·π·k²/N)`, `c[j] = conj(chirp)[j]`.
///
/// Only the **forward** chirp is stored — the inverse chirp is its exact
/// conjugate, applied by sign flip at execution. The kernel spectra are
/// kept per direction (they are index-reversed conjugates of each other;
/// deriving one from the other at execution would destride the pointwise
/// multiply). Execution is two inner power-of-two transforms plus O(m)
/// pointwise work, in reused **f64** scratch planes regardless of the I/O
/// precision — the quadratic chirp phase is the documented precision-tier
/// exception to native-precision execution.
struct Bluestein {
    m: usize,
    inner: Arc<FftPlan>,
    chirp_re: Vec<f64>,
    chirp_im: Vec<f64>,
    kspec_fwd_re: Vec<f64>,
    kspec_fwd_im: Vec<f64>,
    kspec_inv_re: Vec<f64>,
    kspec_inv_im: Vec<f64>,
}

impl Bluestein {
    fn new(n: usize) -> Self {
        let m = (2 * n - 1).next_power_of_two();
        // The inner plan is a power of two, so this never recurses deeper
        // (and plan_for is not holding its cache lock while we build).
        let inner = plan_for(m);
        let mut chirp_re = Vec::with_capacity(n);
        let mut chirp_im = Vec::with_capacity(n);
        for k in 0..n {
            // k² mod 2N keeps the trig argument small (expi has period 2π,
            // π·k²/N has period 2N in k²) — better accuracy for large k.
            let theta = -std::f64::consts::PI * ((k * k) % (2 * n)) as f64 / n as f64;
            chirp_re.push(theta.cos());
            chirp_im.push(theta.sin());
        }
        // Kernel c[j] placed at lags 0, +j and −j (index m−j); m >= 2N−1
        // keeps the two ranges disjoint. Forward kernel: conj(chirp).
        // Inverse kernel: conj(inverse chirp) = the forward chirp itself.
        let kernel_spectrum = |im_sign: f64, inner: &FftPlan| -> (Vec<f64>, Vec<f64>) {
            let mut c_re = vec![0.0f64; m];
            let mut c_im = vec![0.0f64; m];
            c_re[0] = chirp_re[0];
            c_im[0] = im_sign * chirp_im[0];
            for j in 1..n {
                c_re[j] = chirp_re[j];
                c_im[j] = im_sign * chirp_im[j];
                c_re[m - j] = c_re[j];
                c_im[m - j] = c_im[j];
            }
            let mut spec_re = vec![0.0f64; m];
            let mut spec_im = vec![0.0f64; m];
            let mut s = FftScratch::new();
            inner.run_row::<f64>(
                Direction::Forward,
                &c_re,
                &c_im,
                &mut spec_re,
                &mut spec_im,
                &mut s,
            );
            (spec_re, spec_im)
        };
        let (kspec_fwd_re, kspec_fwd_im) = kernel_spectrum(-1.0, &inner);
        let (kspec_inv_re, kspec_inv_im) = kernel_spectrum(1.0, &inner);
        Self {
            m,
            inner,
            chirp_re,
            chirp_im,
            kspec_fwd_re,
            kspec_fwd_im,
            kspec_inv_re,
            kspec_inv_im,
        }
    }

    /// Bytes of precomputed state (shared chirp + per-direction spectra).
    fn table_bytes(&self) -> usize {
        (self.chirp_re.len() + self.chirp_im.len()
            + self.kspec_fwd_re.len()
            + self.kspec_fwd_im.len()
            + self.kspec_inv_re.len()
            + self.kspec_inv_im.len())
            * std::mem::size_of::<f64>()
    }

    fn run_row<T: PlanScalar>(
        &self,
        dir: Direction,
        re_in: &[T],
        im_in: &[T],
        out_re: &mut [T],
        out_im: &mut [T],
        scratch: &mut FftScratch,
    ) {
        let n = re_in.len();
        let m = self.m;
        // Direction sign: the stored chirp is forward; inverse conjugates.
        let cs = if dir == Direction::Inverse { -1.0 } else { 1.0 };
        let (ks_re, ks_im) = match dir {
            Direction::Forward => (&self.kspec_fwd_re, &self.kspec_fwd_im),
            Direction::Inverse => (&self.kspec_inv_re, &self.kspec_inv_im),
        };
        // Take the convolution bank by value so the inner run_row can
        // borrow the scratch again (a Vec move, no copy; put back below).
        let mut bank = std::mem::take(&mut scratch.conv);
        bank.ensure(m);
        for k in 0..n {
            let re = re_in[k].to_f64();
            let im = im_in[k].to_f64();
            let cr = self.chirp_re[k];
            let ci = cs * self.chirp_im[k];
            bank.xr[k] = re * cr - im * ci;
            bank.xi[k] = re * ci + im * cr;
        }
        bank.xr[n..m].fill(0.0);
        bank.xi[n..m].fill(0.0);
        self.inner.run_row::<f64>(
            Direction::Forward,
            &bank.xr[..m],
            &bank.xi[..m],
            &mut bank.yr[..m],
            &mut bank.yi[..m],
            scratch,
        );
        for k in 0..m {
            let ar = bank.yr[k];
            let ai = bank.yi[k];
            bank.yr[k] = ar * ks_re[k] - ai * ks_im[k];
            bank.yi[k] = ar * ks_im[k] + ai * ks_re[k];
        }
        self.inner.run_row::<f64>(
            Direction::Inverse,
            &bank.yr[..m],
            &bank.yi[..m],
            &mut bank.xr[..m],
            &mut bank.xi[..m],
            scratch,
        );
        let inv_m = 1.0 / m as f64;
        for k in 0..n {
            let ar = bank.xr[k] * inv_m;
            let ai = bank.xi[k] * inv_m;
            let cr = self.chirp_re[k];
            let ci = cs * self.chirp_im[k];
            out_re[k] = T::from_f64(ar * cr - ai * ci);
            out_im[k] = T::from_f64(ar * ci + ai * cr);
        }
        scratch.conv = bank;
    }
}

/// One precision's planes inside [`FftScratch`]: two ping-pong re/im
/// pairs plus the rFFT pack bank and the four-step matrix bank. The
/// banks are separate because the paths nest — an rFFT half plan may be
/// four-step, and a [`ConvPlan`] (which stages blocks through `pack`)
/// may run a four-step block transform — and a nested `mem::take` of a
/// shared bank would silently reallocate per call. Grows monotonically;
/// pointer-stable across executions once grown (same contract as the
/// old f64 scratch).
pub struct PrecisionScratch<T> {
    a_re: Vec<T>,
    a_im: Vec<T>,
    b_re: Vec<T>,
    b_im: Vec<T>,
    pack: AuxBank<T>,
    fourstep: AuxBank<T>,
}

impl<T> Default for PrecisionScratch<T> {
    fn default() -> Self {
        Self {
            a_re: Vec::new(),
            a_im: Vec::new(),
            b_re: Vec::new(),
            b_im: Vec::new(),
            pack: AuxBank::default(),
            fourstep: AuxBank::default(),
        }
    }
}

impl<T: PlanScalar> PrecisionScratch<T> {
    /// Grow every plane to at least `len` elements (no-op once large
    /// enough).
    fn ensure(&mut self, len: usize) {
        if self.a_re.len() < len {
            self.a_re.resize(len, T::ZERO);
            self.a_im.resize(len, T::ZERO);
            self.b_re.resize(len, T::ZERO);
            self.b_im.resize(len, T::ZERO);
        }
    }

    /// Current plane capacity in elements (0 = this precision was never
    /// executed through this scratch — the plane-inspection check).
    pub fn capacity(&self) -> usize {
        self.a_re.len()
    }

    #[allow(clippy::type_complexity)]
    fn planes(&mut self, len: usize) -> (&mut [T], &mut [T], &mut [T], &mut [T]) {
        (
            &mut self.a_re[..len],
            &mut self.a_im[..len],
            &mut self.b_re[..len],
            &mut self.b_im[..len],
        )
    }
}

/// Four staging planes usable as an (x, y) complex pair.
struct AuxBank<T> {
    xr: Vec<T>,
    xi: Vec<T>,
    yr: Vec<T>,
    yi: Vec<T>,
}

impl<T> Default for AuxBank<T> {
    fn default() -> Self {
        Self {
            xr: Vec::new(),
            xi: Vec::new(),
            yr: Vec::new(),
            yi: Vec::new(),
        }
    }
}

impl<T: PlanScalar> AuxBank<T> {
    /// Grow every plane to at least `len` elements (no-op once large
    /// enough — same monotonic-growth contract as the main planes).
    fn ensure(&mut self, len: usize) {
        for v in [&mut self.xr, &mut self.xi, &mut self.yr, &mut self.yi] {
            if v.len() < len {
                v.resize(len, T::ZERO);
            }
        }
    }
}

/// Reusable split re/im scratch planes, one set per precision (a native
/// f32 execution never touches — never even allocates — the f64 planes,
/// and vice versa; [`FftScratch::capacity_of`] exposes that for the
/// no-conversion checks). One scratch per worker/thread; each precision's
/// planes grow monotonically to the largest `n·block` served and never
/// reallocate below that.
///
/// Beyond the per-precision ping-pong pairs and rFFT `pack` banks, one
/// shared f64 `conv` bank stages the Bluestein convolution (Bluestein
/// always computes in the f64 tier). Banks are taken by value around
/// inner transforms (a `Vec` move, no copy) so the borrow checker allows
/// re-entering the scratch.
#[derive(Default)]
pub struct FftScratch {
    s64: PrecisionScratch<f64>,
    s32: PrecisionScratch<f32>,
    conv: AuxBank<f64>,
}

impl FftScratch {
    pub fn new() -> Self {
        Self::default()
    }

    /// f64 plane capacity in elements (back-compat accessor; see
    /// [`Self::capacity_of`] for the per-precision view).
    pub fn capacity(&self) -> usize {
        self.s64.capacity()
    }

    /// Base pointer of the first f64 plane — lets tests assert that
    /// repeated executions reuse the same buffers instead of reallocating.
    pub fn base_ptr(&self) -> *const f64 {
        self.s64.a_re.as_ptr()
    }

    /// Plane capacity of one precision's scratch. A scratch that only
    /// ever served native-f32 mixed-radix work reports
    /// `capacity_of::<f64>() == 0` — the plane-inspection proof that the
    /// f32 path performs no f32→f64 conversion.
    pub fn capacity_of<T: PlanScalar>(&self) -> usize {
        T::planes_ref(self).capacity()
    }
}

/// Process-wide plan cache: one immutable `Arc<FftPlan>` per length, built
/// on first use. The lock guards only the map — execution never holds it.
static PLAN_CACHE: OnceLock<Mutex<HashMap<u64, Arc<FftPlan>>>> = OnceLock::new();

/// The cached plan for length `n` (any `n >= 1`), building it on first use.
/// A miss builds outside the lock (twiddle construction is O(n) trig) and
/// the entry API keeps whichever plan landed first, so concurrent
/// first-touch builds neither serialize other lengths nor diverge.
pub fn plan_for(n: usize) -> Arc<FftPlan> {
    let cache = PLAN_CACHE.get_or_init(|| Mutex::new(HashMap::new()));
    if let Some(plan) = cache.lock().unwrap().get(&(n as u64)) {
        return plan.clone();
    }
    let built = Arc::new(FftPlan::new(n));
    cache
        .lock()
        .unwrap()
        .entry(n as u64)
        .or_insert(built)
        .clone()
}

/// Process-wide scratch pool so ad-hoc callers (module `run_f32`, the
/// pool workers) reuse planes instead of allocating per call. Bounded so
/// a burst of threads cannot pin memory forever.
static SCRATCH_POOL: OnceLock<Mutex<Vec<FftScratch>>> = OnceLock::new();
const SCRATCH_POOL_CAP: usize = 16;

/// Borrow a pooled scratch for the duration of `f`, returning it after.
pub fn with_scratch<R>(f: impl FnOnce(&mut FftScratch) -> R) -> R {
    let pool = SCRATCH_POOL.get_or_init(|| Mutex::new(Vec::new()));
    let mut scratch = pool.lock().unwrap().pop().unwrap_or_default();
    let r = f(&mut scratch);
    let mut guard = pool.lock().unwrap();
    if guard.len() < SCRATCH_POOL_CAP {
        guard.push(scratch);
    }
    r
}

/// Worker threads used for row-parallel execution: capped small (this is
/// a simulation backend sharing the host with card worker threads).
/// `FFTSWEEP_FFT_THREADS` overrides, parsed **once** into a `OnceLock` —
/// the serving hot path never re-reads the environment — and the same
/// value sizes the persistent pool. `FFTSWEEP_FFT_THREADS=1` forces the
/// fully pool-free serial path.
pub fn pool_threads() -> usize {
    static THREADS: OnceLock<usize> = OnceLock::new();
    *THREADS.get_or_init(|| {
        if let Ok(v) = std::env::var("FFTSWEEP_FFT_THREADS") {
            if let Ok(t) = v.trim().parse::<usize>() {
                return t.max(1);
            }
        }
        std::thread::available_parallelism()
            .map(|p| p.get())
            .unwrap_or(1)
            .min(4)
    })
}

/// The process-wide persistent FFT worker pool, created on the first
/// parallel batch and reused for every one after — `run_rows` spawns
/// zero threads after this initialization. Workers park on a condvar
/// while idle and are joined cleanly if the pool is ever dropped.
fn fft_pool() -> &'static WorkPool {
    static POOL: OnceLock<WorkPool> = OnceLock::new();
    POOL.get_or_init(|| WorkPool::new("fftsweep-fft", pool_threads()))
}

/// Introspection over the persistent pool (tests, benches, telemetry).
/// Forces pool creation on first call.
pub fn pool_stats() -> PoolStats {
    fft_pool().stats()
}

/// Below this much work a batch runs serially — even a pool submission
/// (enqueue + wake + latch) costs more than it saves. The threshold is
/// set so the standard serving batches (64×1024 and up) parallelize while
/// small/partial batches stay on the zero-handoff serial path.
const PAR_MIN_ROWS: usize = 2;
const PAR_MIN_ELEMS: usize = 1 << 16;

/// Execute `rows` independent transforms, row-parallel through the
/// persistent worker pool when the batch is large enough, serial
/// otherwise. Rows are independent and each runs the identical per-row
/// code, so the pooled result is bit-identical to
/// [`FftPlan::run_rows_serial`] at equal precision.
pub fn run_rows<T: PlanScalar>(
    plan: &FftPlan,
    dir: Direction,
    re: &[T],
    im: &[T],
    rows: usize,
    out_re: &mut [T],
    out_im: &mut [T],
) {
    run_rows_with(plan, dir, re, im, rows, out_re, out_im, pool_threads(), PAR_MIN_ELEMS);
}

/// [`run_rows`] with explicit tuning knobs (`threads` = row-range count
/// submitted to the pool, `min_elems` = serial cutoff). Exposed for tests
/// and benches that need to force the parallel path or reproduce the
/// serial one; serving callers use [`run_rows`].
#[allow(clippy::too_many_arguments)]
pub fn run_rows_with<T: PlanScalar>(
    plan: &FftPlan,
    dir: Direction,
    re: &[T],
    im: &[T],
    rows: usize,
    out_re: &mut [T],
    out_im: &mut [T],
    threads: usize,
    min_elems: usize,
) {
    if rows == 0 {
        return;
    }
    let n = plan.n();
    let threads = threads.min(rows);
    if threads <= 1 || rows < PAR_MIN_ROWS || rows * n < min_elems {
        with_scratch(|s| plan.run_rows_serial(dir, re, im, rows, out_re, out_im, s));
        return;
    }
    let chunk_rows = rows.div_ceil(threads);
    let chunks = out_re[..rows * n]
        .chunks_mut(chunk_rows * n)
        .zip(out_im[..rows * n].chunks_mut(chunk_rows * n))
        .enumerate();
    let mut tasks: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::with_capacity(threads);
    for (ci, (o_re, o_im)) in chunks {
        let start = ci * chunk_rows;
        let rows_here = o_re.len() / n;
        let re_chunk = &re[start * n..(start + rows_here) * n];
        let im_chunk = &im[start * n..(start + rows_here) * n];
        tasks.push(Box::new(move || {
            with_scratch(|s| {
                plan.run_rows_serial(dir, re_chunk, im_chunk, rows_here, o_re, o_im, s)
            });
        }));
    }
    fft_pool().run_scope(tasks);
}

/// Planned forward FFT of one `C64` row — drop-in for `dsp::fft` where the
/// caller wants plan-cache speed with the oracle's interface (and, unlike
/// the oracle, any transform length).
pub fn fft_planned(x: &[C64]) -> Vec<C64> {
    let n = x.len();
    let plan = plan_for(n);
    let re: Vec<f64> = x.iter().map(|c| c.re).collect();
    let im: Vec<f64> = x.iter().map(|c| c.im).collect();
    let mut out_re = vec![0.0f64; n];
    let mut out_im = vec![0.0f64; n];
    with_scratch(|s| plan.run_row(Direction::Forward, &re, &im, &mut out_re, &mut out_im, s));
    out_re
        .into_iter()
        .zip(out_im)
        .map(|(r, i)| C64::new(r, i))
        .collect()
}

/// Number of non-redundant output bins of an N-point real transform.
pub fn rfft_len(n: usize) -> usize {
    n / 2 + 1
}

/// A real-input FFT plan: X = rfft(x) for real x, producing the
/// `n/2 + 1` non-redundant bins (the rest are the conjugate mirror).
///
/// Even `n` packs the input into an `n/2`-point complex transform
/// (`z[k] = x[2k] + i·x[2k+1]`) and unpacks with `n/2` precomputed
/// twiddles (pre-narrowed per precision) — half the butterfly work of the
/// complex transform. When the half plan is mixed radix the whole path is
/// row-blocked and native-`T`; a Bluestein half plan (or odd `n`, which
/// falls back to the full complex plan with a zero imaginary plane) runs
/// per-row. Every length stays supported.
pub struct RfftPlan {
    n: usize,
    kind: RfftKind,
}

enum RfftKind {
    Half {
        plan: Arc<FftPlan>,
        /// Unpack twiddles: `tw[q] = expi(-π·q / (n/2))` for q in 1..n/2
        /// (slot 0 unused).
        tw: TwiddleTable,
    },
    Full {
        plan: Arc<FftPlan>,
    },
}

impl RfftPlan {
    /// Build the plan for real-input length `n` (any `n >= 1`). Prefer
    /// [`rfft_plan_for`], which caches plans process-wide.
    pub fn new(n: usize) -> Self {
        assert!(n >= 1, "rFFT length must be >= 1");
        if n % 2 == 0 {
            let m = n / 2;
            let mut tw_re = Vec::with_capacity(m);
            let mut tw_im = Vec::with_capacity(m);
            for q in 0..m {
                let theta = -std::f64::consts::PI * q as f64 / m as f64;
                tw_re.push(theta.cos());
                tw_im.push(theta.sin());
            }
            Self {
                n,
                kind: RfftKind::Half {
                    plan: plan_for(m),
                    tw: TwiddleTable::new(tw_re, tw_im),
                },
            }
        } else {
            Self {
                n,
                kind: RfftKind::Full { plan: plan_for(n) },
            }
        }
    }

    pub fn n(&self) -> usize {
        self.n
    }

    /// Output bins per row (`n/2 + 1`).
    pub fn out_len(&self) -> usize {
        rfft_len(self.n)
    }

    /// Whether this plan runs through the packed half-length path.
    pub fn half_complex(&self) -> bool {
        matches!(self.kind, RfftKind::Half { .. })
    }

    /// Bytes of precomputed constants (unpack twiddles; the inner complex
    /// plan is shared through the plan cache and counted there).
    pub fn twiddle_bytes(&self) -> usize {
        match &self.kind {
            RfftKind::Half { tw, .. } => tw.bytes(),
            RfftKind::Full { .. } => 0,
        }
    }

    /// Transform one real row into its `n/2 + 1` spectrum bins. `x` must
    /// have length `n`, the outputs length `out_len()`. Steady-state this
    /// performs zero heap allocation (scratch banks are reused); the
    /// arithmetic is native-`T` except through Bluestein inner plans.
    pub fn run_row<T: PlanScalar>(
        &self,
        x: &[T],
        out_re: &mut [T],
        out_im: &mut [T],
        scratch: &mut FftScratch,
    ) {
        let n = self.n;
        let o = self.out_len();
        assert_eq!(x.len(), n, "rfft input length");
        assert_eq!(out_re.len(), o, "rfft re output length");
        assert_eq!(out_im.len(), o, "rfft im output length");
        match &self.kind {
            RfftKind::Half { plan, tw } => {
                let m = n / 2;
                let (tw_re, tw_im) = T::tw(tw);
                let mut bank = std::mem::take(&mut T::planes_mut(scratch).pack);
                bank.ensure(m);
                for k in 0..m {
                    bank.xr[k] = x[2 * k];
                    bank.xi[k] = x[2 * k + 1];
                }
                plan.run_row::<T>(
                    Direction::Forward,
                    &bank.xr[..m],
                    &bank.xi[..m],
                    &mut bank.yr[..m],
                    &mut bank.yi[..m],
                    scratch,
                );
                // Unpack: E[q] = (Z[q] + conj(Z[m−q]))/2 is the even-sample
                // spectrum, O[q] = (Z[q] − conj(Z[m−q]))/(2i) the odd one;
                // X[q] = E[q] + w_q·O[q], X[m] = E[0] − O[0]. DC and Nyquist
                // bins are exactly real for real input.
                let half = T::from_f64(0.5);
                let zr0 = bank.yr[0];
                let zi0 = bank.yi[0];
                out_re[0] = zr0 + zi0;
                out_im[0] = T::ZERO;
                for q in 1..m {
                    let zr = bank.yr[q];
                    let zi = bank.yi[q];
                    let vr = bank.yr[m - q];
                    let vi = -bank.yi[m - q];
                    let er = half * (zr + vr);
                    let ei = half * (zi + vi);
                    let dr = half * (zr - vr);
                    let di = half * (zi - vi);
                    let or_ = di;
                    let oi = -dr;
                    let wr = tw_re[q];
                    let wi = tw_im[q];
                    out_re[q] = er + or_ * wr - oi * wi;
                    out_im[q] = ei + or_ * wi + oi * wr;
                }
                out_re[m] = zr0 - zi0;
                out_im[m] = T::ZERO;
                T::planes_mut(scratch).pack = bank;
            }
            RfftKind::Full { plan } => {
                let mut bank = std::mem::take(&mut T::planes_mut(scratch).pack);
                bank.ensure(n);
                for k in 0..n {
                    bank.xr[k] = x[k];
                    bank.xi[k] = T::ZERO;
                }
                plan.run_row::<T>(
                    Direction::Forward,
                    &bank.xr[..n],
                    &bank.xi[..n],
                    &mut bank.yr[..n],
                    &mut bank.yi[..n],
                    scratch,
                );
                out_re.copy_from_slice(&bank.yr[..o]);
                out_im.copy_from_slice(&bank.yi[..o]);
                T::planes_mut(scratch).pack = bank;
            }
        }
    }

    /// Transform `rows` consecutive real rows serially with one scratch.
    /// `x` is row-major `rows × n`; the outputs `rows × (n/2 + 1)`.
    /// Even lengths with a mixed-radix half plan run row-blocked (packed
    /// straight into batch-major planes — no staging bank, no f64
    /// conversion); other shapes run per-row.
    pub fn run_rows_serial<T: PlanScalar>(
        &self,
        x: &[T],
        rows: usize,
        out_re: &mut [T],
        out_im: &mut [T],
        scratch: &mut FftScratch,
    ) {
        let n = self.n;
        let o = self.out_len();
        assert!(x.len() >= rows * n, "rfft input plane too short");
        assert!(
            out_re.len() >= rows * o && out_im.len() >= rows * o,
            "rfft output planes too short"
        );
        if let RfftKind::Half { plan, tw } = &self.kind {
            // Only monolithic mixed-radix half plans run the fused block
            // path (it drives the stages directly); Bluestein and
            // four-step half plans route per-row below.
            if plan.bluestein.is_none() && plan.four_step.is_none() {
                self.run_rows_half_block(plan, tw, x, rows, out_re, out_im, scratch);
                return;
            }
        }
        for r in 0..rows {
            self.run_row(
                &x[r * n..(r + 1) * n],
                &mut out_re[r * o..(r + 1) * o],
                &mut out_im[r * o..(r + 1) * o],
                scratch,
            );
        }
    }

    /// The row-blocked even-N path: pack a block of rows directly into
    /// batch-major planes (`z[k] = x[2k] + i·x[2k+1]` at `k·bl + r`), run
    /// the half-length stages once over the block, and unpack each row
    /// from the result planes. Per-element arithmetic and order are
    /// identical to [`Self::run_row`], so the block path is bit-identical
    /// to the per-row one at equal precision.
    #[allow(clippy::too_many_arguments)]
    fn run_rows_half_block<T: PlanScalar>(
        &self,
        plan: &FftPlan,
        tw: &TwiddleTable,
        x: &[T],
        rows: usize,
        out_re: &mut [T],
        out_im: &mut [T],
        scratch: &mut FftScratch,
    ) {
        let n = self.n;
        let m = n / 2;
        let o = m + 1;
        let (tw_re, tw_im) = T::tw(tw);
        let half = T::from_f64(0.5);
        let bl_max = row_block::<T>(m.max(1)).min(rows.max(1));
        let s = T::planes_mut(scratch);
        s.ensure(m * bl_max);
        let mut r0 = 0usize;
        while r0 < rows {
            let bl = bl_max.min(rows - r0);
            {
                let (a_re, a_im, _, _) = s.planes(m * bl);
                for r in 0..bl {
                    let row = &x[(r0 + r) * n..][..n];
                    for k in 0..m {
                        a_re[k * bl + r] = row[2 * k];
                        a_im[k * bl + r] = row[2 * k + 1];
                    }
                }
            }
            let in_a = plan.run_block::<T>(Direction::Forward, bl, s);
            let (a_re, a_im, b_re, b_im) = s.planes(m * bl);
            let (yr, yi): (&[T], &[T]) = if in_a { (a_re, a_im) } else { (b_re, b_im) };
            for r in 0..bl {
                let out_r = &mut out_re[(r0 + r) * o..][..o];
                let out_i = &mut out_im[(r0 + r) * o..][..o];
                let zr0 = yr[r];
                let zi0 = yi[r];
                out_r[0] = zr0 + zi0;
                out_i[0] = T::ZERO;
                for q in 1..m {
                    let zr = yr[q * bl + r];
                    let zi = yi[q * bl + r];
                    let vr = yr[(m - q) * bl + r];
                    let vi = -yi[(m - q) * bl + r];
                    let er = half * (zr + vr);
                    let ei = half * (zi + vi);
                    let dr = half * (zr - vr);
                    let di = half * (zi - vi);
                    let or_ = di;
                    let oi = -dr;
                    let wr = tw_re[q];
                    let wi = tw_im[q];
                    out_r[q] = er + or_ * wr - oi * wi;
                    out_i[q] = ei + or_ * wi + oi * wr;
                }
                out_r[m] = zr0 - zi0;
                out_i[m] = T::ZERO;
            }
            r0 += bl;
        }
    }
}

/// Process-wide rFFT plan cache, mirroring [`plan_for`].
static RFFT_PLAN_CACHE: OnceLock<Mutex<HashMap<u64, Arc<RfftPlan>>>> = OnceLock::new();

/// The cached rFFT plan for real-input length `n`, building it on first
/// use (same first-build-wins discipline as [`plan_for`]).
pub fn rfft_plan_for(n: usize) -> Arc<RfftPlan> {
    let cache = RFFT_PLAN_CACHE.get_or_init(|| Mutex::new(HashMap::new()));
    if let Some(plan) = cache.lock().unwrap().get(&(n as u64)) {
        return plan.clone();
    }
    let built = Arc::new(RfftPlan::new(n));
    cache
        .lock()
        .unwrap()
        .entry(n as u64)
        .or_insert(built)
        .clone()
}

/// Execute `rows` independent real transforms through the persistent pool
/// when the batch is large enough (same policy and bit-identity guarantee
/// as [`run_rows`]).
pub fn run_rfft_rows<T: PlanScalar>(
    plan: &RfftPlan,
    x: &[T],
    rows: usize,
    out_re: &mut [T],
    out_im: &mut [T],
) {
    run_rfft_rows_with(plan, x, rows, out_re, out_im, pool_threads(), PAR_MIN_ELEMS);
}

/// [`run_rfft_rows`] with explicit tuning knobs (see [`run_rows_with`]).
pub fn run_rfft_rows_with<T: PlanScalar>(
    plan: &RfftPlan,
    x: &[T],
    rows: usize,
    out_re: &mut [T],
    out_im: &mut [T],
    threads: usize,
    min_elems: usize,
) {
    if rows == 0 {
        return;
    }
    let n = plan.n();
    let o = plan.out_len();
    let threads = threads.min(rows);
    if threads <= 1 || rows < PAR_MIN_ROWS || rows * n < min_elems {
        with_scratch(|s| plan.run_rows_serial(x, rows, out_re, out_im, s));
        return;
    }
    let chunk_rows = rows.div_ceil(threads);
    let chunks = out_re[..rows * o]
        .chunks_mut(chunk_rows * o)
        .zip(out_im[..rows * o].chunks_mut(chunk_rows * o))
        .enumerate();
    let mut tasks: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::with_capacity(threads);
    for (ci, (o_re, o_im)) in chunks {
        let start = ci * chunk_rows;
        let rows_here = o_re.len() / o;
        let x_chunk = &x[start * n..(start + rows_here) * n];
        tasks.push(Box::new(move || {
            with_scratch(|s| plan.run_rows_serial(x_chunk, rows_here, o_re, o_im, s));
        }));
    }
    fft_pool().run_scope(tasks);
}

/// FFT-domain FIR filtering for one signal length and one user-supplied
/// kernel, via batched **overlap-save**: the signal is cut into blocks of
/// `block_len()` samples overlapping by `taps − 1`, each block runs
/// forward FFT → pointwise multiply by the cached kernel spectrum →
/// inverse FFT (the same forward→pointwise→inverse shape as the
/// Bluestein machinery), and the `step()` valid samples per block are
/// written out. The filter is causal with zero initial state:
/// `y[t] = Σ_{j<taps} h[j]·x[t−j]`, `x[t<0] = 0`.
///
/// The kernel spectrum is computed once in f64 at plan build and stored
/// pre-narrowed like a twiddle table, so the per-block pointwise multiply
/// runs in native precision — f32 rows never widen. Plan once per
/// (N, kernel) through [`conv_plan_for`].
pub struct ConvPlan {
    n: usize,
    taps: usize,
    m: usize,
    step: usize,
    fft: Arc<FftPlan>,
    /// Kernel spectrum over the length-`m` block (f64 + pre-narrowed f32
    /// views, one direction — the inverse transform needs no kernel).
    kspec: TwiddleTable,
}

/// The overlap-save block length [`ConvPlan`] picks for `(n, taps)`:
/// the power of two balancing FFT cost against overlap waste — at least
/// 8× the kernel (≥ 87% of each block is valid output), at least 256,
/// and never longer than one padded full-signal transform. Exposed so
/// cost models (the govern CLI) can price conv traffic as the FFT
/// blocks it actually runs without building a plan.
pub fn conv_block_len(n: usize, taps: usize) -> usize {
    assert!(n >= 1, "conv signal length must be >= 1");
    assert!(taps >= 1 && taps <= n, "conv kernel must have 1..=n taps");
    (n + taps - 1)
        .next_power_of_two()
        .min((8 * taps).next_power_of_two().max(256))
}

impl ConvPlan {
    /// Build the plan for signal length `n` and FIR `kernel` (`1..=n`
    /// taps); block geometry per [`conv_block_len`].
    pub fn new(n: usize, kernel: &[f64]) -> Self {
        let taps = kernel.len();
        let m = conv_block_len(n, taps);
        let step = m - taps + 1;
        let fft = plan_for(m);
        let mut h_re = vec![0.0f64; m];
        let h_im = vec![0.0f64; m];
        h_re[..taps].copy_from_slice(kernel);
        let mut spec_re = vec![0.0f64; m];
        let mut spec_im = vec![0.0f64; m];
        let mut s = FftScratch::new();
        fft.run_row::<f64>(Direction::Forward, &h_re, &h_im, &mut spec_re, &mut spec_im, &mut s);
        Self {
            n,
            taps,
            m,
            step,
            fft,
            kspec: TwiddleTable::new(spec_re, spec_im),
        }
    }

    pub fn n(&self) -> usize {
        self.n
    }

    pub fn taps(&self) -> usize {
        self.taps
    }

    /// FFT block length (power of two).
    pub fn block_len(&self) -> usize {
        self.m
    }

    /// Valid output samples produced per block (`block_len − taps + 1`).
    pub fn step(&self) -> usize {
        self.step
    }

    /// Bytes of cached kernel-spectrum state (both precisions; the block
    /// FFT plan is shared through the plan cache and counted there).
    pub fn table_bytes(&self) -> usize {
        self.kspec.bytes()
    }

    /// Full-plane sweeps per output block (forward + inverse transform
    /// passes plus the pointwise multiply) — the bench's pass-count
    /// inspection hook.
    pub fn passes_per_block(&self) -> usize {
        2 * self.fft.pass_count() + 1
    }

    /// Filter one row: `x` must have length `n`, `y` likewise. Steady
    /// state performs zero heap allocation (the `pack` staging bank and
    /// the FFT planes are reused across calls).
    pub fn run_row<T: PlanScalar>(&self, x: &[T], y: &mut [T], scratch: &mut FftScratch) {
        let n = self.n;
        let (m, k, step) = (self.m, self.taps, self.step);
        assert_eq!(x.len(), n, "conv input length");
        assert_eq!(y.len(), n, "conv output length");
        let (ks_re, ks_im) = T::tw(&self.kspec);
        // Stage blocks through the pack bank (taken by value so the
        // block FFT can re-borrow the scratch; conv never nests inside
        // the rFFT path, which is pack's other user).
        let mut bank = std::mem::take(&mut T::planes_mut(scratch).pack);
        bank.ensure(m);
        let inv_m = T::from_f64(1.0 / m as f64);
        let mut t0 = 0usize;
        while t0 < n {
            // The block covers input samples [t0−(taps−1), t0−(taps−1)+m);
            // history before the row start reads as zero (causal FIR,
            // zero initial state), as does the tail past the row end.
            let base = t0 as isize - (k as isize - 1);
            for i in 0..m {
                let t = base + i as isize;
                bank.xr[i] = if t >= 0 && (t as usize) < n {
                    x[t as usize]
                } else {
                    T::ZERO
                };
                bank.xi[i] = T::ZERO;
            }
            self.fft.run_row::<T>(
                Direction::Forward,
                &bank.xr[..m],
                &bank.xi[..m],
                &mut bank.yr[..m],
                &mut bank.yi[..m],
                scratch,
            );
            for i in 0..m {
                let ar = bank.yr[i];
                let ai = bank.yi[i];
                bank.yr[i] = ar * ks_re[i] - ai * ks_im[i];
                bank.yi[i] = ar * ks_im[i] + ai * ks_re[i];
            }
            self.fft.run_row::<T>(
                Direction::Inverse,
                &bank.yr[..m],
                &bank.yi[..m],
                &mut bank.xr[..m],
                &mut bank.xi[..m],
                scratch,
            );
            // Positions [taps−1, m) of the circular result equal the
            // linear convolution — the overlap-save discard rule.
            let take = step.min(n - t0);
            for i in 0..take {
                y[t0 + i] = bank.xr[k - 1 + i] * inv_m;
            }
            t0 += step;
        }
        T::planes_mut(scratch).pack = bank;
    }

    /// Filter `rows` consecutive rows serially with one scratch (`x` and
    /// `y` row-major `rows × n`).
    pub fn run_rows_serial<T: PlanScalar>(
        &self,
        x: &[T],
        rows: usize,
        y: &mut [T],
        scratch: &mut FftScratch,
    ) {
        let n = self.n;
        assert!(x.len() >= rows * n, "conv input plane too short");
        assert!(y.len() >= rows * n, "conv output plane too short");
        for r in 0..rows {
            self.run_row(&x[r * n..(r + 1) * n], &mut y[r * n..(r + 1) * n], scratch);
        }
    }
}

/// The standard synthetic filterbank kernel: a Hamming-windowed lowpass
/// with unit DC gain. This is what the simulated runtime builds for
/// `conv` artifacts (taps carried in the manifest's harmonics field), so
/// both backends and the tests agree on the kernel bits.
pub fn synthetic_kernel(taps: usize) -> Vec<f64> {
    assert!(taps >= 1, "kernel needs at least one tap");
    if taps == 1 {
        return vec![1.0];
    }
    let mut h: Vec<f64> = (0..taps)
        .map(|j| {
            0.54 - 0.46 * (2.0 * std::f64::consts::PI * j as f64 / (taps - 1) as f64).cos()
        })
        .collect();
    let sum: f64 = h.iter().sum();
    for v in &mut h {
        *v /= sum;
    }
    h
}

/// FNV-1a over the kernel's bit patterns — the cache key discriminant
/// for [`conv_plan_for`] (two kernels of equal length but different
/// coefficients must not share a plan).
fn kernel_fingerprint(kernel: &[f64]) -> u64 {
    let mut hash = 0xcbf29ce484222325u64;
    for &v in kernel {
        for b in v.to_bits().to_le_bytes() {
            hash ^= b as u64;
            hash = hash.wrapping_mul(0x100000001b3);
        }
    }
    hash
}

/// Process-wide convolution plan cache keyed by (n, taps, kernel bits),
/// mirroring [`plan_for`]'s first-build-wins discipline.
static CONV_PLAN_CACHE: OnceLock<Mutex<HashMap<(u64, u64, u64), Arc<ConvPlan>>>> = OnceLock::new();

/// The cached convolution plan for (signal length, kernel), building it
/// on first use — "plan once per (N, kernel)".
pub fn conv_plan_for(n: usize, kernel: &[f64]) -> Arc<ConvPlan> {
    let key = (n as u64, kernel.len() as u64, kernel_fingerprint(kernel));
    let cache = CONV_PLAN_CACHE.get_or_init(|| Mutex::new(HashMap::new()));
    if let Some(plan) = cache.lock().unwrap().get(&key) {
        return plan.clone();
    }
    let built = Arc::new(ConvPlan::new(n, kernel));
    cache.lock().unwrap().entry(key).or_insert(built).clone()
}

/// Filter `rows` independent rows through the persistent pool when the
/// batch is large enough (same policy and bit-identity guarantee as
/// [`run_rows`]: each row runs the identical per-row code).
pub fn run_conv_rows<T: PlanScalar>(plan: &ConvPlan, x: &[T], rows: usize, y: &mut [T]) {
    run_conv_rows_with(plan, x, rows, y, pool_threads(), PAR_MIN_ELEMS);
}

/// [`run_conv_rows`] with explicit tuning knobs (see [`run_rows_with`]).
pub fn run_conv_rows_with<T: PlanScalar>(
    plan: &ConvPlan,
    x: &[T],
    rows: usize,
    y: &mut [T],
    threads: usize,
    min_elems: usize,
) {
    if rows == 0 {
        return;
    }
    let n = plan.n();
    let threads = threads.min(rows);
    if threads <= 1 || rows < PAR_MIN_ROWS || rows * n < min_elems {
        with_scratch(|s| plan.run_rows_serial(x, rows, y, s));
        return;
    }
    let chunk_rows = rows.div_ceil(threads);
    let mut tasks: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::with_capacity(threads);
    for (ci, y_chunk) in y[..rows * n].chunks_mut(chunk_rows * n).enumerate() {
        let start = ci * chunk_rows;
        let rows_here = y_chunk.len() / n;
        let x_chunk = &x[start * n..(start + rows_here) * n];
        tasks.push(Box::new(move || {
            with_scratch(|s| plan.run_rows_serial(x_chunk, rows_here, y_chunk, s));
        }));
    }
    fft_pool().run_scope(tasks);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsp::fft::{dft_naive, fft};
    use crate::util::rng::Rng;

    fn rand_row(n: usize, seed: u64) -> (Vec<f64>, Vec<f64>) {
        let mut r = Rng::new(seed);
        (
            (0..n).map(|_| r.gauss()).collect(),
            (0..n).map(|_| r.gauss()).collect(),
        )
    }

    #[test]
    fn radix2_equiv_stages_telescopes_to_log2n_for_smooth_lengths() {
        // Σ log₂(radix) over any smooth schedule is log₂(N) exactly,
        // monolithic or four-step; Bluestein pays two inner transforms of
        // the padded power of two instead.
        for n in [256usize, 1000, 1024, 1536, 16384, 1 << 18] {
            let plan = plan_for(n);
            let want = (n as f64).log2();
            assert!(
                (plan.radix2_equiv_stages() - want).abs() < 1e-9,
                "n={n}: {} vs log2 {}",
                plan.radix2_equiv_stages(),
                want
            );
        }
        let blue = plan_for(19321); // 139², non-smooth
        assert_eq!(blue.algorithm(), PlanAlgorithm::Bluestein);
        let m = (2 * 19321 - 1usize).next_power_of_two();
        assert!((blue.radix2_equiv_stages() - 2.0 * (m as f64).log2()).abs() < 1e-9);
    }

    #[test]
    fn bytes_moved_tracks_plane_sweeps_and_precision() {
        use crate::types::Precision;
        // Monolithic: stage-count plane sweeps plus the twiddle stream.
        let p1024 = plan_for(1024);
        let stages = p1024.stage_radices().len() as u64;
        assert_eq!(
            p1024.bytes_moved(Precision::Fp32),
            stages * 2 * 8 * 1024 + p1024.twiddle_bytes() as u64
        );
        // f64 planes double the plane traffic, not the table bytes.
        assert!(p1024.bytes_moved(Precision::Fp64) > p1024.bytes_moved(Precision::Fp32));
        // Four-step at 2^18 moves more bytes than a same-length
        // monolithic *per sweep* accounting would suggest is free: both
        // are within 2x of each other, and both dwarf the 1024 plan.
        let big = plan_for(1 << 18);
        assert_eq!(big.algorithm(), PlanAlgorithm::FourStep);
        assert!(big.bytes_moved(Precision::Fp32) > 100 * p1024.bytes_moved(Precision::Fp32));
        // Bluestein executes in f64 regardless of the requested tier.
        let blue = plan_for(19321);
        assert_eq!(
            blue.bytes_moved(Precision::Fp32),
            blue.bytes_moved(Precision::Fp64)
        );
    }

    #[test]
    fn plan_matches_naive_dft_all_lengths() {
        // The issue's acceptance grid: every power of two in 2..=4096.
        let mut n = 2usize;
        while n <= 4096 {
            let (re, im) = rand_row(n, n as u64);
            let x: Vec<C64> = re
                .iter()
                .zip(&im)
                .map(|(&r, &i)| C64::new(r, i))
                .collect();
            let want = dft_naive(&x);
            let plan = plan_for(n);
            let mut out_re = vec![0.0f64; n];
            let mut out_im = vec![0.0f64; n];
            let mut s = FftScratch::new();
            plan.run_row(Direction::Forward, &re, &im, &mut out_re, &mut out_im, &mut s);
            let tol = 1e-8 * n as f64;
            for i in 0..n {
                assert!(
                    (out_re[i] - want[i].re).abs() < tol && (out_im[i] - want[i].im).abs() < tol,
                    "n={n} bin {i}: ({}, {}) vs {:?}",
                    out_re[i],
                    out_im[i],
                    want[i]
                );
            }
            n *= 2;
        }
    }

    #[test]
    fn radix2_baseline_is_bit_identical_to_stockham_oracle() {
        // The oracle contract moved to the explicit radix-2 schedule when
        // the high-radix kernels landed: the default plan reorders
        // rounding (fewer, wider butterflies), so bit identity is pinned
        // on `new_radix2` and the default is tolerance-tested against it
        // in `high_radix_schedule_matches_radix2_baseline`.
        for n in [2usize, 8, 64, 1024] {
            let (re, im) = rand_row(n, 7 + n as u64);
            let x: Vec<C64> = re.iter().zip(&im).map(|(&r, &i)| C64::new(r, i)).collect();
            let want = fft(&x);
            let plan = FftPlan::new_radix2(n);
            let mut out_re = vec![0.0f64; n];
            let mut out_im = vec![0.0f64; n];
            let mut s = FftScratch::new();
            plan.run_row(Direction::Forward, &re, &im, &mut out_re, &mut out_im, &mut s);
            for i in 0..n {
                assert_eq!(out_re[i].to_bits(), want[i].re.to_bits(), "n={n} bin {i} re");
                assert_eq!(out_im[i].to_bits(), want[i].im.to_bits(), "n={n} bin {i} im");
            }
        }
    }

    #[test]
    fn high_radix_schedule_matches_radix2_baseline() {
        // The default schedule (radix 8/4-first) against the bit-identity
        // oracle schedule, at f64 tolerance: same transform, different
        // rounding order.
        for n in [8usize, 64, 256, 1000, 1024, 1536, 4096] {
            let (re, im) = rand_row(n, 31 + n as u64);
            let hi = FftPlan::new_monolithic(n);
            let lo = FftPlan::new_radix2(n);
            let mut s = FftScratch::new();
            let (mut hr, mut hi_) = (vec![0.0f64; n], vec![0.0f64; n]);
            hi.run_row(Direction::Forward, &re, &im, &mut hr, &mut hi_, &mut s);
            let (mut lr, mut li) = (vec![0.0f64; n], vec![0.0f64; n]);
            lo.run_row(Direction::Forward, &re, &im, &mut lr, &mut li, &mut s);
            let tol = 1e-10 * n as f64;
            for i in 0..n {
                assert!(
                    (hr[i] - lr[i]).abs() < tol && (hi_[i] - li[i]).abs() < tol,
                    "n={n} bin {i}: high-radix ({}, {}) vs radix-2 ({}, {})",
                    hr[i],
                    hi_[i],
                    lr[i],
                    li[i]
                );
            }
        }
    }

    #[test]
    fn high_radix_schedule_strictly_lowers_pass_count() {
        // The issue's acceptance assertion: whenever 4 | N the compiler
        // must emit radix-4/8 stages and the pass count must be strictly
        // below the radix-2-only schedule's.
        for n in [16usize, 64, 256, 1000, 1024, 1536, 2560, 4096] {
            assert_eq!(n % 4, 0, "test grid must be divisible by 4");
            let hi = FftPlan::new_monolithic(n);
            let lo = FftPlan::new_radix2(n);
            assert!(
                hi.pass_count() < lo.pass_count(),
                "n={n}: high-radix {} passes vs radix-2 {}",
                hi.pass_count(),
                lo.pass_count()
            );
            assert!(
                hi.stage_radices().iter().any(|&r| r == 4 || r == 8),
                "n={n}: schedule {:?} has no radix-4/8 stage",
                hi.stage_radices()
            );
        }
        // 2^k runs in ⌈k/3⌉ passes: 1024 = 8·8·8·2.
        assert_eq!(FftPlan::new_monolithic(1024).stage_radices(), vec![8, 8, 8, 2]);
        assert_eq!(FftPlan::new_monolithic(1024).pass_count(), 4);
        // Default plans (through the cache) use the high-radix schedule.
        assert!(plan_for(1024).stage_radices().iter().any(|&r| r == 8));
    }

    #[test]
    fn blocked_f64_rows_stay_bit_identical_to_stockham_oracle() {
        // The row-blocked batch-major sweep must not perturb a single bit
        // of the f64 pow2 path: block size changes memory layout only,
        // never per-element operation order. Pinned on the radix-2
        // baseline schedule (the one sharing `fft_stockham`'s rounding
        // order).
        let n = 512usize;
        let rows = 24usize; // > row_block::<f64>(512) ⇒ several full blocks
        let (re, im) = rand_row(rows * n, 99);
        let plan = FftPlan::new_radix2(n);
        let mut out_re = vec![0.0f64; rows * n];
        let mut out_im = vec![0.0f64; rows * n];
        let mut s = FftScratch::new();
        plan.run_rows_serial(Direction::Forward, &re, &im, rows, &mut out_re, &mut out_im, &mut s);
        for row in 0..rows {
            let off = row * n;
            let x: Vec<C64> = (0..n).map(|i| C64::new(re[off + i], im[off + i])).collect();
            let want = fft(&x);
            for i in 0..n {
                assert_eq!(out_re[off + i].to_bits(), want[i].re.to_bits(), "r{row} b{i}");
                assert_eq!(out_im[off + i].to_bits(), want[i].im.to_bits(), "r{row} b{i}");
            }
        }
    }

    #[test]
    fn inverse_roundtrips() {
        // Also exercises the conjugation-derived inverse twiddles (no
        // stored inverse tables anymore).
        let n = 256usize;
        let (re, im) = rand_row(n, 13);
        let plan = plan_for(n);
        let mut s = FftScratch::new();
        let (mut fr, mut fi) = (vec![0.0; n], vec![0.0; n]);
        plan.run_row(Direction::Forward, &re, &im, &mut fr, &mut fi, &mut s);
        let (mut br, mut bi) = (vec![0.0; n], vec![0.0; n]);
        plan.run_row(Direction::Inverse, &fr, &fi, &mut br, &mut bi, &mut s);
        for i in 0..n {
            assert!((br[i] / n as f64 - re[i]).abs() < 1e-10, "bin {i}");
            assert!((bi[i] / n as f64 - im[i]).abs() < 1e-10, "bin {i}");
        }
    }

    #[test]
    fn plan_cache_returns_the_same_arc() {
        let a = plan_for(512);
        let b = plan_for(512);
        assert!(Arc::ptr_eq(&a, &b), "cache hit must return the cached plan");
        let c = plan_for(1024);
        assert!(!Arc::ptr_eq(&a, &c));
    }

    #[test]
    fn scratch_is_pointer_stable_across_executions() {
        // The no-alloc acceptance check: run the scratch path twice (and
        // then at a smaller n) and assert the planes were not reallocated.
        let n = 1024usize;
        let plan = plan_for(n);
        let (re, im) = rand_row(n, 3);
        let (mut or1, mut oi1) = (vec![0.0; n], vec![0.0; n]);
        let mut s = FftScratch::new();
        plan.run_row(Direction::Forward, &re, &im, &mut or1, &mut oi1, &mut s);
        let ptr = s.base_ptr();
        let cap = s.capacity();
        plan.run_row(Direction::Forward, &re, &im, &mut or1, &mut oi1, &mut s);
        assert_eq!(s.base_ptr(), ptr, "second run must reuse the same planes");
        assert_eq!(s.capacity(), cap);
        // Smaller transform through the same scratch: still no realloc.
        let small = plan_for(64);
        let (sre, sim_) = rand_row(64, 4);
        let (mut sor, mut soi) = (vec![0.0; 64], vec![0.0; 64]);
        small.run_row(Direction::Forward, &sre, &sim_, &mut sor, &mut soi, &mut s);
        assert_eq!(s.base_ptr(), ptr, "smaller n must not shrink/realloc");
    }

    #[test]
    fn scratch_reuse_across_differing_batch_occupancies() {
        // One scratch serving batches of different row counts (the partial
        // vs full PackedBatch case) stays correct and allocation-stable.
        let n = 256usize;
        let plan = plan_for(n);
        let mut s = FftScratch::new();
        for rows in [1usize, 3, 8, 2, 8] {
            let (re, im) = rand_row(rows * n, rows as u64);
            let re32: Vec<f32> = re.iter().map(|&v| v as f32).collect();
            let im32: Vec<f32> = im.iter().map(|&v| v as f32).collect();
            let mut or_ = vec![0.0f32; rows * n];
            let mut oi = vec![0.0f32; rows * n];
            plan.run_rows_serial(Direction::Forward, &re32, &im32, rows, &mut or_, &mut oi, &mut s);
            for r in 0..rows {
                let off = r * n;
                let x: Vec<C64> = (0..n)
                    .map(|i| C64::new(re32[off + i] as f64, im32[off + i] as f64))
                    .collect();
                let want = fft(&x);
                for i in 0..n {
                    assert!(
                        (or_[off + i] as f64 - want[i].re).abs() < 1e-2,
                        "rows={rows} r={r} bin {i}"
                    );
                }
            }
        }
    }

    #[test]
    fn f32_native_path_never_touches_f64_planes() {
        // Plane inspection: a scratch that only served native-f32
        // mixed-radix work must never have allocated an f64 plane — the
        // structural proof that no f32→f64 conversion happened.
        let n = 1024usize;
        let rows = 4usize;
        let plan = plan_for(n);
        let mut r = Rng::new(17);
        let re: Vec<f32> = (0..rows * n).map(|_| r.gauss() as f32).collect();
        let im: Vec<f32> = (0..rows * n).map(|_| r.gauss() as f32).collect();
        let mut o_re = vec![0.0f32; rows * n];
        let mut o_im = vec![0.0f32; rows * n];
        let mut s = FftScratch::new();
        plan.run_rows_serial(Direction::Forward, &re, &im, rows, &mut o_re, &mut o_im, &mut s);
        assert_eq!(s.capacity_of::<f64>(), 0, "f32 path must not grow f64 planes");
        assert!(s.capacity_of::<f32>() >= n, "f32 planes must be in use");
        // The rFFT packed path (mixed-radix half plan) is f32-native too.
        let rplan = rfft_plan_for(n);
        let x: Vec<f32> = (0..rows * n).map(|_| r.gauss() as f32).collect();
        let o = rplan.out_len();
        let mut r_re = vec![0.0f32; rows * o];
        let mut r_im = vec![0.0f32; rows * o];
        let mut s2 = FftScratch::new();
        rplan.run_rows_serial(&x, rows, &mut r_re, &mut r_im, &mut s2);
        assert_eq!(s2.capacity_of::<f64>(), 0, "rfft f32 path must stay f32");
    }

    #[test]
    fn bluestein_f32_runs_in_the_f64_tier() {
        // The documented precision-tier exception: Bluestein computes in
        // f64 planes whatever the I/O precision (the quadratic chirp
        // phase wants the headroom); the f32 planes stay untouched.
        let n = 1009usize;
        let plan = plan_for(n);
        let mut r = Rng::new(23);
        let re: Vec<f32> = (0..n).map(|_| r.gauss() as f32).collect();
        let im: Vec<f32> = (0..n).map(|_| r.gauss() as f32).collect();
        let mut o_re = vec![0.0f32; n];
        let mut o_im = vec![0.0f32; n];
        let mut s = FftScratch::new();
        plan.run_row(Direction::Forward, &re, &im, &mut o_re, &mut o_im, &mut s);
        assert!(s.capacity_of::<f64>() > 0, "bluestein uses the f64 tier");
        assert_eq!(s.capacity_of::<f32>(), 0, "f32 planes unused by bluestein");
    }

    #[test]
    fn plan_twiddle_footprint_is_single_direction() {
        // The plan-size regression gate: stage tables are stored for ONE
        // direction only (inverse = conjugation at execution). Each
        // complex entry costs 24 B (f64 re+im, pre-narrowed f32 re+im);
        // storing both directions again would double this and fail here.
        // Mirrors the high-radix stage selection; note the total
        // telescopes to n−1 for ANY full factorization (Σ m·(radix−1)
        // over n → n/r₁ → … → 1), so the radix-8/4 preference changes
        // pass count but not table size.
        fn expected_entries(n: usize) -> usize {
            let mut total = 0usize;
            let mut n_cur = n;
            while n_cur > 1 {
                let radix = if n_cur % 8 == 0 {
                    8
                } else if n_cur % 4 == 0 {
                    4
                } else if n_cur % 2 == 0 {
                    2
                } else if n_cur % 3 == 0 {
                    3
                } else {
                    5
                };
                let m = n_cur / radix;
                total += m * (radix - 1);
                n_cur = m;
            }
            assert_eq!(total, n - 1, "twiddle entries telescope to n-1");
            total
        }
        for n in [64usize, 1000, 1024, 1536, 3125] {
            let plan = FftPlan::new(n);
            assert_eq!(
                plan.twiddle_bytes(),
                expected_entries(n) * 24,
                "n={n}: stage twiddles must be one direction only"
            );
        }
        // Pow2 check spelled out: sum of m over stages = n−1.
        assert_eq!(FftPlan::new(1024).twiddle_bytes(), 1023 * 24);
        // rFFT unpack table: n/2 entries, one direction.
        assert_eq!(RfftPlan::new(1024).twiddle_bytes(), 512 * 24);
        // Bluestein: shared chirp (2·n planes) + two kernel spectra
        // (4·m planes), all f64.
        let b = FftPlan::new(1009);
        let m = (2 * 1009usize - 1).next_power_of_two();
        assert_eq!(b.twiddle_bytes(), (2 * 1009 + 4 * m) * 8);
    }

    #[test]
    fn prop_row_parallel_is_bit_identical_to_serial() {
        crate::util::prop::check(
            "planner row-parallel == serial",
            |rng| {
                let n = 1usize << rng.range_u64(3, 10); // 8..=1024
                let rows = rng.range_u64(1, 40) as usize;
                let seed = rng.range_u64(0, 1 << 32);
                (n, rows, seed)
            },
            |&(n, rows, seed)| {
                let plan = plan_for(n);
                let mut r = Rng::new(seed);
                let re: Vec<f32> = (0..rows * n).map(|_| r.gauss() as f32).collect();
                let im: Vec<f32> = (0..rows * n).map(|_| r.gauss() as f32).collect();
                let mut ser_re = vec![0.0f32; rows * n];
                let mut ser_im = vec![0.0f32; rows * n];
                let mut s = FftScratch::new();
                plan.run_rows_serial(
                    Direction::Forward,
                    &re,
                    &im,
                    rows,
                    &mut ser_re,
                    &mut ser_im,
                    &mut s,
                );
                let mut par_re = vec![0.0f32; rows * n];
                let mut par_im = vec![0.0f32; rows * n];
                // min_elems = 0 forces the pool path even for the small
                // cases the generator produces.
                run_rows_with(
                    &plan,
                    Direction::Forward,
                    &re,
                    &im,
                    rows,
                    &mut par_re,
                    &mut par_im,
                    4,
                    0,
                );
                for i in 0..rows * n {
                    if ser_re[i].to_bits() != par_re[i].to_bits()
                        || ser_im[i].to_bits() != par_im[i].to_bits()
                    {
                        return Err(format!(
                            "n={n} rows={rows} elem {i}: serial ({}, {}) vs parallel ({}, {})",
                            ser_re[i], ser_im[i], par_re[i], par_im[i]
                        ));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn pool_f64_rows_bit_identical_to_serial() {
        // The satellite's equal-precision pool check, f64 flavor.
        let n = 512usize;
        let rows = 16usize;
        let (re, im) = rand_row(rows * n, 77);
        let plan = plan_for(n);
        let mut ser_re = vec![0.0f64; rows * n];
        let mut ser_im = vec![0.0f64; rows * n];
        let mut s = FftScratch::new();
        plan.run_rows_serial(Direction::Forward, &re, &im, rows, &mut ser_re, &mut ser_im, &mut s);
        let mut par_re = vec![0.0f64; rows * n];
        let mut par_im = vec![0.0f64; rows * n];
        run_rows_with(&plan, Direction::Forward, &re, &im, rows, &mut par_re, &mut par_im, 4, 0);
        for i in 0..rows * n {
            assert_eq!(ser_re[i].to_bits(), par_re[i].to_bits(), "elem {i} re");
            assert_eq!(ser_im[i].to_bits(), par_im[i].to_bits(), "elem {i} im");
        }
    }

    #[test]
    fn run_rows_reuses_the_persistent_pool_across_calls() {
        // The zero-spawn acceptance check: after the pool exists, repeated
        // parallel batches create no new OS threads.
        let n = 64usize;
        let rows = 8usize;
        let plan = plan_for(n);
        let mut r = Rng::new(55);
        let re: Vec<f32> = (0..rows * n).map(|_| r.gauss() as f32).collect();
        let im: Vec<f32> = (0..rows * n).map(|_| r.gauss() as f32).collect();
        let mut o_re = vec![0.0f32; rows * n];
        let mut o_im = vec![0.0f32; rows * n];
        for _ in 0..4 {
            run_rows_with(&plan, Direction::Forward, &re, &im, rows, &mut o_re, &mut o_im, 4, 0);
        }
        let s1 = pool_stats();
        for _ in 0..4 {
            run_rows_with(&plan, Direction::Forward, &re, &im, rows, &mut o_re, &mut o_im, 4, 0);
        }
        let s2 = pool_stats();
        assert_eq!(s1.spawned_total, s2.spawned_total, "no spawns after init");
        assert_eq!(s2.spawned_total, s2.workers as u64, "workers spawned once");
        assert!(s2.executed_total > s1.executed_total, "pool actually ran tasks");
    }

    #[test]
    fn row_block_is_tuned_for_cache_residency() {
        // 4 planes × n × block × width within the 256 KiB half-L2 budget.
        assert_eq!(row_block::<f32>(1024), 16);
        assert_eq!(row_block::<f64>(1024), 8);
        assert_eq!(row_block::<f32>(64), 32, "small n clamps at 32");
        assert_eq!(row_block::<f32>(1 << 16), 1, "huge n degenerates to per-row");
    }

    #[test]
    fn f64_rows_match_oracle() {
        // Pool execution of the radix-2 baseline stays bit-identical to
        // the Stockham oracle (rows are independent; same per-row code).
        let n = 512usize;
        let rows = 4usize;
        let (re, im) = rand_row(rows * n, 21);
        let plan = FftPlan::new_radix2(n);
        let mut out_re = vec![0.0f64; rows * n];
        let mut out_im = vec![0.0f64; rows * n];
        run_rows(&plan, Direction::Forward, &re, &im, rows, &mut out_re, &mut out_im);
        for row in 0..rows {
            let off = row * n;
            let x: Vec<C64> = (0..n).map(|i| C64::new(re[off + i], im[off + i])).collect();
            let want = fft(&x);
            for i in 0..n {
                assert_eq!(out_re[off + i].to_bits(), want[i].re.to_bits(), "r{row} b{i}");
                assert_eq!(out_im[off + i].to_bits(), want[i].im.to_bits(), "r{row} b{i}");
            }
        }
    }

    #[test]
    fn length_one_plan_copies() {
        let plan = plan_for(1);
        let mut s = FftScratch::new();
        let (mut or_, mut oi) = (vec![0.0f64], vec![0.0f64]);
        plan.run_row(Direction::Forward, &[2.5], &[-1.5], &mut or_, &mut oi, &mut s);
        assert_eq!(or_[0], 2.5);
        assert_eq!(oi[0], -1.5);
    }

    /// Tolerance-check one planned forward transform against the naive DFT.
    fn check_against_naive(n: usize) {
        let (re, im) = rand_row(n, 0xC0FFEE ^ n as u64);
        let x: Vec<C64> = re.iter().zip(&im).map(|(&r, &i)| C64::new(r, i)).collect();
        let want = dft_naive(&x);
        let got = fft_planned(&x);
        let tol = 1e-8 * n as f64;
        for i in 0..n {
            assert!(
                (got[i].re - want[i].re).abs() < tol && (got[i].im - want[i].im).abs() < tol,
                "n={n} bin {i}: ({}, {}) vs {:?}",
                got[i].re,
                got[i].im,
                want[i]
            );
        }
    }

    #[test]
    fn every_length_2_to_128_matches_naive_dft() {
        // Exhaustive bottom of the acceptance grid: all small lengths,
        // covering every factor-class transition (pow2, 2^a·3^b·5^c, primes,
        // prime squares, odd composites).
        for n in 2..=128usize {
            check_against_naive(n);
        }
    }

    #[test]
    fn every_length_129_to_320_matches_naive_dft() {
        for n in 129..=320usize {
            check_against_naive(n);
        }
    }

    #[test]
    fn targeted_large_lengths_match_naive_dft() {
        // The acceptance grid's upper reach, one representative per factor
        // class: primes (331, 2017, 4093), prime-square-adjacent odd smooth
        // (729, 2187, 3125), the issue's serving lengths (1000, 1536), a
        // 7-smooth Bluestein composite (4095 = 3²·5·7·13) and pow2 4096.
        let lengths = [
            331usize, 500, 625, 729, 1000, 1009, 1536, 2017, 2187, 3125, 4093, 4095, 4096,
        ];
        for n in lengths {
            check_against_naive(n);
        }
    }

    #[test]
    fn sampled_grid_2_to_4096_roundtrips_and_spot_checks() {
        // The rest of the 2..=4096 grid, sampled with a prime stride so no
        // factor class is systematically skipped. Two cheap checks per
        // length: forward→inverse/N roundtrip (O(n log n)) and the DC bin
        // against the direct sum (catches permutation/twiddle errors the
        // roundtrip alone could mask). The roundtrip also exercises the
        // conjugation-derived inverse on every plan class.
        let mut n = 321usize;
        while n <= 4096 {
            let (re, im) = rand_row(n, n as u64);
            let plan = plan_for(n);
            let mut s = FftScratch::new();
            let (mut fr, mut fi) = (vec![0.0f64; n], vec![0.0f64; n]);
            plan.run_row(Direction::Forward, &re, &im, &mut fr, &mut fi, &mut s);
            let dc_re: f64 = re.iter().sum();
            let dc_im: f64 = im.iter().sum();
            let tol = 1e-8 * n as f64;
            assert!(
                (fr[0] - dc_re).abs() < tol && (fi[0] - dc_im).abs() < tol,
                "n={n}: DC bin ({}, {}) vs ({dc_re}, {dc_im})",
                fr[0],
                fi[0]
            );
            let (mut br, mut bi) = (vec![0.0f64; n], vec![0.0f64; n]);
            plan.run_row(Direction::Inverse, &fr, &fi, &mut br, &mut bi, &mut s);
            for i in 0..n {
                assert!(
                    (br[i] / n as f64 - re[i]).abs() < 1e-7
                        && (bi[i] / n as f64 - im[i]).abs() < 1e-7,
                    "n={n} roundtrip bin {i}"
                );
            }
            n += 29;
        }
    }

    #[test]
    fn algorithm_classification() {
        assert_eq!(plan_for(4096).algorithm(), PlanAlgorithm::MixedRadix);
        assert_eq!(plan_for(1000).algorithm(), PlanAlgorithm::MixedRadix); // 2³·5³
        assert_eq!(plan_for(1536).algorithm(), PlanAlgorithm::MixedRadix); // 2⁹·3
        assert_eq!(plan_for(1009).algorithm(), PlanAlgorithm::Bluestein); // prime
        assert_eq!(plan_for(19321).algorithm(), PlanAlgorithm::Bluestein); // 139²
        assert_eq!(plan_for(4095).algorithm(), PlanAlgorithm::Bluestein); // 7·13 factors
        // The large-N tier: smooth lengths past the L2 budget compile to
        // the four-step split; the threshold boundary stays monolithic.
        assert_eq!(plan_for(16384).algorithm(), PlanAlgorithm::MixedRadix);
        assert_eq!(plan_for(1 << 15).algorithm(), PlanAlgorithm::FourStep);
        assert_eq!(plan_for(1 << 18).algorithm(), PlanAlgorithm::FourStep);
        assert_eq!(plan_for(3 << 14).algorithm(), PlanAlgorithm::FourStep); // 3·2¹⁴
        assert!(supports(1) && supports(1009));
        assert!(!supports(0));
    }

    #[test]
    fn four_step_selects_balanced_l2_resident_split() {
        let plan = plan_for(1 << 18);
        let (n1, n2) = plan.four_step_split().expect("2^18 must be four-step");
        assert_eq!(n1 * n2, 1 << 18);
        assert_eq!((n1, n2), (512, 512), "pow2 splits at sqrt");
        // Each sub-plan must itself be small enough for the monolithic
        // L2-resident path.
        assert!(n1 <= FOUR_STEP_DEFAULT_THRESHOLD && n2 <= FOUR_STEP_DEFAULT_THRESHOLD);
        // The split twiddle tables stay O(n/256 + 256), not O(n): the
        // monolithic schedule's telescoped (n−1)-entry footprint would be
        // ~6 MB here, the factored inter-step table is ~30 KB.
        assert!(
            plan.twiddle_bytes() <= ((1 << 18) / FOURSTEP_TW_LO + FOURSTEP_TW_LO + 2) * 24,
            "split twiddle factorization must keep the table compact"
        );
        let mono = FftPlan::new_monolithic(1 << 18);
        assert!(plan.twiddle_bytes() < mono.twiddle_bytes() / 100);
        // Four-step runs col + twiddle + row sweeps — one more pass than
        // the monolithic schedule, each L2-resident instead of streaming
        // the whole plane (the bench's large_n section measures the win).
        assert_eq!(plan.pass_count(), mono.pass_count() + 1);
    }

    #[test]
    fn four_step_matches_monolithic_across_large_sample() {
        // The issue's in-test budget: 2^14..2^16 forced splits compared
        // against the monolithic high-radix plan over the full output
        // (2^18 runs in `four_step_large_n_roundtrip_and_spot_bins`).
        for n in [1usize << 14, 3 << 13, 1 << 16] {
            let fs = FftPlan::new_four_step(n).expect("split must exist");
            assert!(fs.is_four_step());
            let mono = FftPlan::new_monolithic(n);
            let (re, im) = rand_row(n, n as u64 ^ 0x45);
            let mut s = FftScratch::new();
            let (mut fr, mut fi) = (vec![0.0f64; n], vec![0.0f64; n]);
            fs.run_row(Direction::Forward, &re, &im, &mut fr, &mut fi, &mut s);
            let (mut mr, mut mi) = (vec![0.0f64; n], vec![0.0f64; n]);
            mono.run_row(Direction::Forward, &re, &im, &mut mr, &mut mi, &mut s);
            // Same transform, different rounding order: relative L2.
            let mut err = 0.0f64;
            let mut norm = 0.0f64;
            for i in 0..n {
                let dr = fr[i] - mr[i];
                let di = fi[i] - mi[i];
                err += dr * dr + di * di;
                norm += mr[i] * mr[i] + mi[i] * mi[i];
            }
            let rel = (err / norm.max(1e-30)).sqrt();
            assert!(rel < 1e-12, "n={n}: four-step vs monolithic rel l2 {rel:.3e}");
        }
    }

    #[test]
    fn four_step_large_n_roundtrip_and_spot_bins() {
        // The auto-selected path at 2^18: DC and a non-trivial bin
        // against O(n) direct sums, plus the forward→inverse/N roundtrip
        // (which exercises the conjugated inter-step twiddles).
        let n = 1usize << 18;
        let plan = plan_for(n);
        assert_eq!(plan.algorithm(), PlanAlgorithm::FourStep);
        let (re, im) = rand_row(n, 0x218);
        let mut s = FftScratch::new();
        let (mut fr, mut fi) = (vec![0.0f64; n], vec![0.0f64; n]);
        plan.run_row(Direction::Forward, &re, &im, &mut fr, &mut fi, &mut s);
        let tol = 1e-8 * n as f64;
        for k in [0usize, 1, 4097, n / 2 + 3] {
            let (mut wr, mut wi) = (0.0f64, 0.0f64);
            for t in 0..n {
                let theta = -2.0 * std::f64::consts::PI * ((k as u64 * t as u64) % n as u64)
                    as f64
                    / n as f64;
                let (c, si_) = (theta.cos(), theta.sin());
                wr += re[t] * c - im[t] * si_;
                wi += re[t] * si_ + im[t] * c;
            }
            assert!(
                (fr[k] - wr).abs() < tol && (fi[k] - wi).abs() < tol,
                "bin {k}: ({}, {}) vs direct ({wr}, {wi})",
                fr[k],
                fi[k]
            );
        }
        let (mut br, mut bi) = (vec![0.0f64; n], vec![0.0f64; n]);
        plan.run_row(Direction::Inverse, &fr, &fi, &mut br, &mut bi, &mut s);
        for i in (0..n).step_by(997) {
            assert!(
                (br[i] / n as f64 - re[i]).abs() < 1e-9
                    && (bi[i] / n as f64 - im[i]).abs() < 1e-9,
                "roundtrip elem {i}"
            );
        }
    }

    #[test]
    fn four_step_pool_rows_bit_identical_to_serial() {
        // The satellite's pool check on the new path: four-step rows
        // route per-row in both serial and pooled execution, so the
        // pool must reproduce serial bit for bit.
        let n = 1usize << 14;
        let rows = 4usize;
        let plan = FftPlan::new_four_step(n).expect("split");
        let mut r = Rng::new(0x4574);
        let re: Vec<f32> = (0..rows * n).map(|_| r.gauss() as f32).collect();
        let im: Vec<f32> = (0..rows * n).map(|_| r.gauss() as f32).collect();
        let mut ser_re = vec![0.0f32; rows * n];
        let mut ser_im = vec![0.0f32; rows * n];
        let mut s = FftScratch::new();
        plan.run_rows_serial(Direction::Forward, &re, &im, rows, &mut ser_re, &mut ser_im, &mut s);
        let mut par_re = vec![0.0f32; rows * n];
        let mut par_im = vec![0.0f32; rows * n];
        run_rows_with(&plan, Direction::Forward, &re, &im, rows, &mut par_re, &mut par_im, 4, 0);
        for i in 0..rows * n {
            assert_eq!(ser_re[i].to_bits(), par_re[i].to_bits(), "elem {i} re");
            assert_eq!(ser_im[i].to_bits(), par_im[i].to_bits(), "elem {i} im");
        }
    }

    #[test]
    fn four_step_f32_native_within_tiered_tolerance() {
        // The tiered-tolerance satellite on the new path: f32-native
        // four-step output vs its own f64 execution, under the log₂N
        // bound — and the f32 run must never touch f64 planes (the
        // four-step bank is per-precision like everything else).
        let n = 1usize << 14;
        let plan = FftPlan::new_four_step(n).expect("split");
        let mut r = Rng::new(0x4532);
        let re32: Vec<f32> = (0..n).map(|_| r.gauss() as f32).collect();
        let im32: Vec<f32> = (0..n).map(|_| r.gauss() as f32).collect();
        let rew: Vec<f64> = re32.iter().map(|&v| v as f64).collect();
        let imw: Vec<f64> = im32.iter().map(|&v| v as f64).collect();
        let mut s = FftScratch::new();
        let (mut wr, mut wi) = (vec![0.0f64; n], vec![0.0f64; n]);
        plan.run_row(Direction::Forward, &rew, &imw, &mut wr, &mut wi, &mut s);
        let mut s32 = FftScratch::new();
        let (mut gr, mut gi) = (vec![0.0f32; n], vec![0.0f32; n]);
        plan.run_row(Direction::Forward, &re32, &im32, &mut gr, &mut gi, &mut s32);
        assert_eq!(s32.capacity_of::<f64>(), 0, "f32 four-step must stay f32-native");
        let err = rel_l2(&gr, &wr, &wi, &gi);
        let tol = f32_rel_tol(n);
        assert!(err < tol, "four-step f32 rel l2 {err:.3e} > tol {tol:.3e}");
    }

    #[test]
    fn four_step_scratch_bank_is_reused() {
        // The no-alloc contract extends to the dedicated four-step bank.
        let n = 1usize << 15;
        let plan = plan_for(n);
        assert!(plan.is_four_step());
        let (re, im) = rand_row(n, 5);
        let (mut or_, mut oi) = (vec![0.0f64; n], vec![0.0f64; n]);
        let mut s = FftScratch::new();
        plan.run_row(Direction::Forward, &re, &im, &mut or_, &mut oi, &mut s);
        let ptr = s.s64.fourstep.xr.as_ptr();
        let cap = s.s64.fourstep.xr.len();
        plan.run_row(Direction::Forward, &re, &im, &mut or_, &mut oi, &mut s);
        assert_eq!(s.s64.fourstep.xr.as_ptr(), ptr, "four-step bank must be reused");
        assert_eq!(s.s64.fourstep.xr.len(), cap);
    }

    /// Direct causal FIR: `y[t] = Σ_{j<taps} h[j]·x[t−j]`, zero history.
    fn conv_direct(x: &[f64], h: &[f64]) -> Vec<f64> {
        let n = x.len();
        let mut y = vec![0.0f64; n];
        for t in 0..n {
            let mut acc = 0.0f64;
            for (j, &hj) in h.iter().enumerate() {
                if t >= j {
                    acc += hj * x[t - j];
                }
            }
            y[t] = acc;
        }
        y
    }

    #[test]
    fn conv_plan_matches_direct_convolution() {
        // The acceptance criterion: FFT→multiply→iFFT equals the direct
        // FIR to f64 tolerance, across block regimes — single-block
        // (m covers the padded signal), many-block overlap-save, and a
        // tap count large enough that the overlap dominates.
        for (n, taps) in [(256usize, 9usize), (1000, 33), (1024, 129), (4096, 257)] {
            let h = synthetic_kernel(taps);
            let plan = ConvPlan::new(n, &h);
            assert!(plan.block_len().is_power_of_two());
            assert_eq!(plan.step(), plan.block_len() - taps + 1);
            let (x, _) = rand_row(n, (n * taps) as u64);
            let want = conv_direct(&x, &h);
            let mut y = vec![0.0f64; n];
            let mut s = FftScratch::new();
            plan.run_row::<f64>(&x, &mut y, &mut s);
            let tol = 1e-10 * taps as f64;
            for t in 0..n {
                assert!(
                    (y[t] - want[t]).abs() < tol,
                    "n={n} taps={taps} t={t}: {} vs {}",
                    y[t],
                    want[t]
                );
            }
        }
    }

    #[test]
    fn conv_f32_native_within_tiered_tolerance() {
        // Native-f32 filtering vs the f64 direct FIR, under the same
        // log₂-depth bound as the FFT paths (the pointwise multiply uses
        // the pre-narrowed kernel spectrum — no f64 planes may appear).
        let (n, taps) = (1024usize, 65usize);
        let h = synthetic_kernel(taps);
        let plan = ConvPlan::new(n, &h);
        let mut r = Rng::new(0xC0);
        let x32: Vec<f32> = (0..n).map(|_| r.gauss() as f32).collect();
        let x64: Vec<f64> = x32.iter().map(|&v| v as f64).collect();
        let want = conv_direct(&x64, &h);
        let mut y = vec![0.0f32; n];
        let mut s = FftScratch::new();
        plan.run_row::<f32>(&x32, &mut y, &mut s);
        assert_eq!(s.capacity_of::<f64>(), 0, "f32 conv must stay f32-native");
        let mut err = 0.0f64;
        let mut norm = 0.0f64;
        for t in 0..n {
            let d = y[t] as f64 - want[t];
            err += d * d;
            norm += want[t] * want[t];
        }
        let rel = (err / norm.max(1e-30)).sqrt();
        // Forward + inverse + pointwise: double a single transform's stage
        // depth, with 2x headroom on top (the bound is per-FFT).
        let tol = 4.0 * f32_rel_tol(plan.block_len());
        assert!(rel < tol, "conv f32 rel l2 {rel:.3e} > tol {tol:.3e}");
    }

    #[test]
    fn conv_plan_cache_is_keyed_by_kernel_bits() {
        let h33 = synthetic_kernel(33);
        let a = conv_plan_for(512, &h33);
        let b = conv_plan_for(512, &h33);
        assert!(Arc::ptr_eq(&a, &b), "same (n, kernel) must share one plan");
        let c = conv_plan_for(512, &synthetic_kernel(65));
        assert!(!Arc::ptr_eq(&a, &c), "different kernels must not share");
        let mut bumped = h33.clone();
        bumped[0] += 1e-12; // same taps, different bits
        let d = conv_plan_for(512, &bumped);
        assert!(!Arc::ptr_eq(&a, &d), "cache key must cover kernel bits");
        assert!(a.table_bytes() > 0 && a.passes_per_block() >= 3);
    }

    #[test]
    fn conv_pool_rows_bit_identical_to_serial() {
        // The pool guarantee extends to the conv workload: chunked rows
        // run the identical per-row code, so pooled output is bit-equal.
        let (n, taps, rows) = (1000usize, 33usize, 5usize);
        let plan = conv_plan_for(n, &synthetic_kernel(taps));
        let mut r = Rng::new(0xC0117);
        let x: Vec<f32> = (0..rows * n).map(|_| r.gauss() as f32).collect();
        let mut ser = vec![0.0f32; rows * n];
        let mut s = FftScratch::new();
        plan.run_rows_serial(&x, rows, &mut ser, &mut s);
        let mut par = vec![0.0f32; rows * n];
        run_conv_rows_with(&plan, &x, rows, &mut par, 4, 0);
        for i in 0..rows * n {
            assert_eq!(ser[i].to_bits(), par[i].to_bits(), "elem {i}");
        }
    }

    #[test]
    fn synthetic_kernel_has_unit_dc_gain() {
        for taps in [1usize, 9, 33, 129] {
            let h = synthetic_kernel(taps);
            assert_eq!(h.len(), taps);
            let dc: f64 = h.iter().sum();
            assert!((dc - 1.0).abs() < 1e-12, "taps={taps} dc={dc}");
            assert!(h.iter().all(|&v| v > 0.0), "Hamming lowpass taps are positive");
        }
    }

    #[test]
    fn prop_mixed_radix_row_parallel_is_bit_identical_to_serial() {
        // The non-pow2 sibling of the pow2 property test: lengths drawn
        // from every plan class (mixed radix and Bluestein).
        let menu = [12usize, 60, 100, 144, 243, 251, 360, 625, 1000, 1536];
        crate::util::prop::for_all(
            crate::util::prop::PropConfig { cases: 48, seed: 0x0FF6 },
            "planner mixed-radix row-parallel == serial",
            |rng| {
                let n = menu[rng.below(menu.len() as u64) as usize];
                let rows = rng.range_u64(1, 12) as usize;
                let seed = rng.range_u64(0, 1 << 32);
                (n, rows, seed)
            },
            |&(n, rows, seed)| {
                let plan = plan_for(n);
                let mut r = Rng::new(seed);
                let re: Vec<f32> = (0..rows * n).map(|_| r.gauss() as f32).collect();
                let im: Vec<f32> = (0..rows * n).map(|_| r.gauss() as f32).collect();
                let mut ser_re = vec![0.0f32; rows * n];
                let mut ser_im = vec![0.0f32; rows * n];
                let mut s = FftScratch::new();
                plan.run_rows_serial(
                    Direction::Forward,
                    &re,
                    &im,
                    rows,
                    &mut ser_re,
                    &mut ser_im,
                    &mut s,
                );
                let mut par_re = vec![0.0f32; rows * n];
                let mut par_im = vec![0.0f32; rows * n];
                run_rows_with(
                    &plan,
                    Direction::Forward,
                    &re,
                    &im,
                    rows,
                    &mut par_re,
                    &mut par_im,
                    4,
                    0,
                );
                for i in 0..rows * n {
                    if ser_re[i].to_bits() != par_re[i].to_bits()
                        || ser_im[i].to_bits() != par_im[i].to_bits()
                    {
                        return Err(format!("n={n} rows={rows} elem {i} diverged"));
                    }
                }
                Ok(())
            },
        );
    }

    /// Relative-L2 tolerance for native-f32 output vs the f64 oracle:
    /// rounding accumulates with stage depth, so the bound scales with
    /// log₂N (the tiered tolerance policy — Bluestein computes in f64 and
    /// clears it trivially; native-f32 mixed radix sits well inside it).
    fn f32_rel_tol(n: usize) -> f64 {
        16.0 * (n.max(2) as f64).log2() * f32::EPSILON as f64
    }

    fn rel_l2(got: &[f32], want_re: &[f64], want_im: &[f64], got_im: &[f32]) -> f64 {
        let mut err = 0.0f64;
        let mut norm = 0.0f64;
        for i in 0..want_re.len() {
            let dr = got[i] as f64 - want_re[i];
            let di = got_im[i] as f64 - want_im[i];
            err += dr * dr + di * di;
            norm += want_re[i] * want_re[i] + want_im[i] * want_im[i];
        }
        (err / norm.max(1e-30)).sqrt()
    }

    #[test]
    fn prop_f32_native_matches_f64_oracle_within_tiered_tolerance() {
        // The issue's satellite property test: f32-native output vs the
        // f64 oracle under the log₂N-scaled relative bound, across the
        // 2..=4096 grid's plan classes — pow2, mixed radix, Bluestein —
        // plus the rFFT path on the same lengths.
        let mixed = [6usize, 12, 48, 100, 144, 360, 625, 1000, 1536, 2160, 3840];
        let blue = [7usize, 11, 97, 251, 1009, 2017, 4093];
        crate::util::prop::for_all(
            crate::util::prop::PropConfig { cases: 48, seed: 0xF32F },
            "f32-native within tiered tolerance of the f64 oracle",
            |rng| {
                let n = match rng.below(3) {
                    0 => 1usize << rng.range_u64(1, 12), // 2..=4096
                    1 => mixed[rng.below(mixed.len() as u64) as usize],
                    _ => blue[rng.below(blue.len() as u64) as usize],
                };
                let rfft = rng.below(3) == 0;
                let seed = rng.range_u64(0, 1 << 32);
                (n, rfft, seed)
            },
            |&(n, rfft, seed)| {
                let mut r = Rng::new(seed);
                let tol = f32_rel_tol(n);
                if rfft {
                    let x64: Vec<f64> = (0..n).map(|_| r.gauss()).collect();
                    let x32: Vec<f32> = x64.iter().map(|&v| v as f32).collect();
                    // Widen the f32 input so both precisions see the same
                    // signal; the oracle is the f64 execution of it.
                    let xw: Vec<f64> = x32.iter().map(|&v| v as f64).collect();
                    let rplan = rfft_plan_for(n);
                    let o = rplan.out_len();
                    let mut s = FftScratch::new();
                    let (mut wr, mut wi) = (vec![0.0f64; o], vec![0.0f64; o]);
                    rplan.run_row(&xw, &mut wr, &mut wi, &mut s);
                    let (mut gr, mut gi) = (vec![0.0f32; o], vec![0.0f32; o]);
                    rplan.run_row(&x32, &mut gr, &mut gi, &mut s);
                    let err = rel_l2(&gr, &wr, &wi, &gi);
                    if err > tol {
                        return Err(format!("rfft n={n}: rel l2 {err:.3e} > tol {tol:.3e}"));
                    }
                } else {
                    let (re64, im64) = rand_row(n, seed ^ 0xA5);
                    let re32: Vec<f32> = re64.iter().map(|&v| v as f32).collect();
                    let im32: Vec<f32> = im64.iter().map(|&v| v as f32).collect();
                    let rew: Vec<f64> = re32.iter().map(|&v| v as f64).collect();
                    let imw: Vec<f64> = im32.iter().map(|&v| v as f64).collect();
                    let plan = plan_for(n);
                    let mut s = FftScratch::new();
                    let (mut wr, mut wi) = (vec![0.0f64; n], vec![0.0f64; n]);
                    plan.run_row(Direction::Forward, &rew, &imw, &mut wr, &mut wi, &mut s);
                    let (mut gr, mut gi) = (vec![0.0f32; n], vec![0.0f32; n]);
                    plan.run_row(Direction::Forward, &re32, &im32, &mut gr, &mut gi, &mut s);
                    let err = rel_l2(&gr, &wr, &wi, &gi);
                    if err > tol {
                        return Err(format!(
                            "{:?} n={n}: rel l2 {err:.3e} > tol {tol:.3e}",
                            plan.algorithm()
                        ));
                    }
                }
                Ok(())
            },
        );
    }

    /// rFFT vs the complex plan on the same real signal.
    fn check_rfft(n: usize) {
        let (xs, _) = rand_row(n, 0x5EED ^ n as u64);
        let x: Vec<C64> = xs.iter().map(|&r| C64::new(r, 0.0)).collect();
        let want = fft_planned(&x);
        let rplan = rfft_plan_for(n);
        let o = rplan.out_len();
        let mut out_re = vec![0.0f64; o];
        let mut out_im = vec![0.0f64; o];
        let mut s = FftScratch::new();
        rplan.run_row(&xs, &mut out_re, &mut out_im, &mut s);
        let tol = 1e-8 * n as f64;
        for k in 0..o {
            assert!(
                (out_re[k] - want[k].re).abs() < tol && (out_im[k] - want[k].im).abs() < tol,
                "n={n} bin {k}: ({}, {}) vs {:?}",
                out_re[k],
                out_im[k],
                want[k]
            );
        }
    }

    #[test]
    fn rfft_matches_complex_reference() {
        // Even lengths run the packed half-complex path (2018 = 2·1009
        // exercises a Bluestein half-plan); odd lengths the full fallback.
        for n in [2usize, 4, 16, 100, 256, 1000, 1536, 2018, 4096] {
            assert!(rfft_plan_for(n).half_complex(), "n={n} should pack");
            check_rfft(n);
        }
        for n in [1usize, 3, 15, 81, 1009] {
            assert!(!rfft_plan_for(n).half_complex(), "n={n} is odd");
            check_rfft(n);
        }
    }

    #[test]
    fn rfft_dc_and_nyquist_bins_are_exactly_real() {
        let n = 1024usize;
        let (xs, _) = rand_row(n, 77);
        let rplan = rfft_plan_for(n);
        let o = rplan.out_len();
        let (mut or_, mut oi) = (vec![0.0f64; o], vec![0.0f64; o]);
        let mut s = FftScratch::new();
        rplan.run_row(&xs, &mut or_, &mut oi, &mut s);
        assert_eq!(oi[0], 0.0, "DC bin must be exactly real");
        assert_eq!(oi[n / 2], 0.0, "Nyquist bin must be exactly real");
        let dc: f64 = xs.iter().sum();
        assert!((or_[0] - dc).abs() < 1e-9 * n as f64);
    }

    #[test]
    fn rfft_block_path_is_bit_identical_to_per_row() {
        // run_rows_serial takes the row-blocked batch-major path for a
        // mixed-radix half plan; per-row run_row is the reference. Same
        // per-element arithmetic ⇒ same bits.
        let n = 1000usize;
        let rows = 5usize;
        let rplan = rfft_plan_for(n);
        let o = rplan.out_len();
        let mut r = Rng::new(31);
        let x: Vec<f32> = (0..rows * n).map(|_| r.gauss() as f32).collect();
        let mut blk_re = vec![0.0f32; rows * o];
        let mut blk_im = vec![0.0f32; rows * o];
        let mut s = FftScratch::new();
        rplan.run_rows_serial(&x, rows, &mut blk_re, &mut blk_im, &mut s);
        let mut row_re = vec![0.0f32; rows * o];
        let mut row_im = vec![0.0f32; rows * o];
        let mut s2 = FftScratch::new();
        for rr in 0..rows {
            rplan.run_row(
                &x[rr * n..(rr + 1) * n],
                &mut row_re[rr * o..(rr + 1) * o],
                &mut row_im[rr * o..(rr + 1) * o],
                &mut s2,
            );
        }
        for i in 0..rows * o {
            assert_eq!(blk_re[i].to_bits(), row_re[i].to_bits(), "elem {i} re");
            assert_eq!(blk_im[i].to_bits(), row_im[i].to_bits(), "elem {i} im");
        }
    }

    #[test]
    fn rfft_rows_parallel_matches_serial() {
        let n = 1000usize;
        let rows = 8usize;
        let rplan = rfft_plan_for(n);
        let o = rplan.out_len();
        let mut r = Rng::new(31);
        let x: Vec<f32> = (0..rows * n).map(|_| r.gauss() as f32).collect();
        let mut ser_re = vec![0.0f32; rows * o];
        let mut ser_im = vec![0.0f32; rows * o];
        let mut s = FftScratch::new();
        rplan.run_rows_serial(&x, rows, &mut ser_re, &mut ser_im, &mut s);
        let mut par_re = vec![0.0f32; rows * o];
        let mut par_im = vec![0.0f32; rows * o];
        // min_elems = 0 forces the pool path.
        run_rfft_rows_with(&rplan, &x, rows, &mut par_re, &mut par_im, 4, 0);
        assert_eq!(ser_re, par_re);
        assert_eq!(ser_im, par_im);
    }

    #[test]
    fn rfft_cache_returns_the_same_arc() {
        let a = rfft_plan_for(640);
        let b = rfft_plan_for(640);
        assert!(Arc::ptr_eq(&a, &b));
    }

    #[test]
    fn bluestein_reuses_scratch_without_reallocating() {
        // The no-alloc contract extends to the Bluestein convolution bank:
        // after the first run through one scratch, repeats are stable.
        let n = 1009usize;
        let plan = plan_for(n);
        let (re, im) = rand_row(n, 4);
        let (mut or_, mut oi) = (vec![0.0f64; n], vec![0.0f64; n]);
        let mut s = FftScratch::new();
        plan.run_row(Direction::Forward, &re, &im, &mut or_, &mut oi, &mut s);
        let ptr = s.conv.xr.as_ptr();
        let cap = s.conv.xr.len();
        plan.run_row(Direction::Forward, &re, &im, &mut or_, &mut oi, &mut s);
        assert_eq!(s.conv.xr.as_ptr(), ptr, "conv bank must be reused");
        assert_eq!(s.conv.xr.len(), cap);
    }

    #[test]
    #[should_panic(expected = ">= 1")]
    fn rejects_zero_length() {
        FftPlan::new(0);
    }
}
