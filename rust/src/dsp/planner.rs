//! Planned FFT execution: a general plan compiler for the sim backend.
//!
//! `fft_stockham` (the numerical oracle in `dsp::fft`) recomputes every
//! twiddle with `sin`/`cos` per butterfly column per stage, allocates two
//! fresh `Vec<C64>` per transform, and only handles powers of two. An
//! [`FftPlan`] hoists all of that out of the row loop, exactly the way
//! cuFFT plans do, and serves **every** length:
//!
//!   * mixed-radix Stockham decomposition with radix-2/3/5 butterflies and
//!     per-stage twiddle tables (both directions), precomputed once per
//!     transform length and cached process-wide ([`plan_for`]) — the
//!     radix-2 schedule is bit-identical to `fft_stockham`,
//!   * Bluestein's chirp-z algorithm as the fallback for lengths with
//!     prime factors other than 2/3/5: the length-N transform becomes a
//!     circular convolution of padded length `m = next_pow2(2N-1)` run
//!     through a cached power-of-two plan, with the chirp and the kernel
//!     spectrum precomputed at plan-build time,
//!   * a real-input path ([`RfftPlan`]): an even-N real transform packs
//!     into an N/2 complex transform plus an O(N) unpack; odd N falls back
//!     to the complex plan with a zero imaginary plane,
//!   * execution in split re/im (SoA) `f64` scratch planes owned by a
//!     reusable [`FftScratch`] — **no trig and no heap allocation inside
//!     the per-row inner loop**,
//!   * row-parallel batch execution over std scoped threads
//!     ([`run_rows`], [`run_rfft_rows`]), bit-identical to the serial path
//!     because rows are independent and each thread runs the same
//!     per-row code.
//!
//! For power-of-two lengths the butterfly schedule and operation order
//! mirror `fft_stockham` exactly, so planned output is bit-identical to
//! the oracle in f64.

use std::collections::HashMap;
use std::sync::{Arc, Mutex, OnceLock};

use crate::dsp::fft::C64;

/// Transform direction. `Forward` matches `dsp::fft` (sign −1);
/// `Inverse` is the unnormalized adjoint (sign +1) — callers scale by
/// 1/N themselves, as with `fft_stockham(x, 1.0)`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Direction {
    Forward,
    Inverse,
}

/// Sample type a plan can execute on. The arithmetic is always f64 in the
/// scratch planes; this only governs the load/store conversion.
pub trait PlanScalar: Copy + Send + Sync {
    fn to_f64(self) -> f64;
    fn from_f64(x: f64) -> Self;
}

impl PlanScalar for f32 {
    #[inline]
    fn to_f64(self) -> f64 {
        self as f64
    }
    #[inline]
    fn from_f64(x: f64) -> Self {
        x as f32
    }
}

impl PlanScalar for f64 {
    #[inline]
    fn to_f64(self) -> f64 {
        self
    }
    #[inline]
    fn from_f64(x: f64) -> Self {
        x
    }
}

/// Which decomposition a plan compiled to (exposed for tests, docs and
/// the pricing layer's sanity checks).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PlanAlgorithm {
    /// Stockham mixed-radix (every prime factor in {2, 3, 5}).
    MixedRadix,
    /// Chirp-z convolution through a padded power-of-two plan.
    Bluestein,
}

/// Every length >= 1 has a plan (mixed radix or the Bluestein fallback).
/// The coordinator checks this at submit time so an unplannable job is a
/// typed error instead of a worker-thread panic.
pub fn supports(n: usize) -> bool {
    n >= 1
}

/// The sign-folded butterfly constants of one stage's radix kernel.
#[derive(Clone, Copy)]
enum Kernel {
    R2,
    /// `s3 = sign * sqrt(3)/2` — the imaginary part of the radix-3 root.
    R3 { s3: f64 },
    /// `c1/c2 = cos(2pi/5), cos(4pi/5)`; `s1/s2` sign-folded sines.
    R5 { c1: f64, c2: f64, s1: f64, s2: f64 },
}

/// One Stockham stage: `m` butterfly groups of `radix` inputs at `stride`
/// columns each, with the `(radix-1)` twiddles per group precomputed as
/// `tw[p*(radix-1) + (j-1)] = expi(theta0 * p * j)`. The radix itself is
/// carried by the `kernel` variant.
struct Stage {
    m: usize,
    stride: usize,
    kernel: Kernel,
    tw_re: Vec<f64>,
    tw_im: Vec<f64>,
}

/// A reusable execution plan for one transform length: per-stage twiddle
/// tables for both directions (mixed radix), or the precomputed chirp /
/// kernel-spectrum pair (Bluestein). Immutable after construction; share
/// it freely across threads (the cache hands out `Arc<FftPlan>`).
pub struct FftPlan {
    n: usize,
    fwd: Vec<Stage>,
    inv: Vec<Stage>,
    bluestein: Option<Bluestein>,
}

impl FftPlan {
    /// Build the plan for length `n` (any `n >= 1`). Prefer [`plan_for`],
    /// which caches plans process-wide.
    pub fn new(n: usize) -> Self {
        assert!(n >= 1, "FFT length must be >= 1");
        let mut rem = n;
        for r in [2usize, 3, 5] {
            while rem % r == 0 {
                rem /= r;
            }
        }
        if rem == 1 {
            Self {
                n,
                fwd: Self::stages(n, -1.0),
                inv: Self::stages(n, 1.0),
                bluestein: None,
            }
        } else {
            Self {
                n,
                fwd: Vec::new(),
                inv: Vec::new(),
                bluestein: Some(Bluestein::new(n)),
            }
        }
    }

    fn stages(n: usize, sign: f64) -> Vec<Stage> {
        let mut out = Vec::new();
        let mut n_cur = n;
        let mut stride = 1usize;
        while n_cur > 1 {
            // Radix 2 first keeps the power-of-two schedule identical to
            // `fft_stockham`; remaining 3s and 5s follow.
            let radix = if n_cur % 2 == 0 {
                2
            } else if n_cur % 3 == 0 {
                3
            } else {
                5
            };
            debug_assert_eq!(n_cur % radix, 0, "stage radix must divide n_cur");
            let m = n_cur / radix;
            // Same expression as fft_stockham so radix-2 twiddles are
            // bit-identical ((p * 1) as f64 == p as f64).
            let theta0 = sign * 2.0 * std::f64::consts::PI / n_cur as f64;
            let mut tw_re = Vec::with_capacity(m * (radix - 1));
            let mut tw_im = Vec::with_capacity(m * (radix - 1));
            for p in 0..m {
                for j in 1..radix {
                    let theta = theta0 * (p * j) as f64;
                    tw_re.push(theta.cos());
                    tw_im.push(theta.sin());
                }
            }
            let kernel = match radix {
                2 => Kernel::R2,
                3 => Kernel::R3 {
                    s3: sign * (3.0f64.sqrt() / 2.0),
                },
                _ => {
                    let fifth = 2.0 * std::f64::consts::PI / 5.0;
                    Kernel::R5 {
                        c1: fifth.cos(),
                        c2: (2.0 * fifth).cos(),
                        s1: sign * fifth.sin(),
                        s2: sign * (2.0 * fifth).sin(),
                    }
                }
            };
            out.push(Stage {
                m,
                stride,
                kernel,
                tw_re,
                tw_im,
            });
            n_cur = m;
            stride *= radix;
        }
        out
    }

    pub fn n(&self) -> usize {
        self.n
    }

    /// Which decomposition this plan compiled to.
    pub fn algorithm(&self) -> PlanAlgorithm {
        if self.bluestein.is_some() {
            PlanAlgorithm::Bluestein
        } else {
            PlanAlgorithm::MixedRadix
        }
    }

    /// Transform one row already loaded into `scratch`'s A planes; returns
    /// `true` when the result ended in the A planes (even stage count).
    /// Mixed-radix plans only (Bluestein routes through `run_row`).
    fn run_loaded(&self, dir: Direction, s: &mut FftScratch) -> bool {
        let stages = match dir {
            Direction::Forward => &self.fwd,
            Direction::Inverse => &self.inv,
        };
        let n = self.n;
        let (a_re, a_im, b_re, b_im) = s.planes(n);
        let mut in_a = true;
        for st in stages {
            if in_a {
                st.pass(a_re, a_im, b_re, b_im);
            } else {
                st.pass(b_re, b_im, a_re, a_im);
            }
            in_a = !in_a;
        }
        in_a
    }

    /// Transform one row: load `re_in`/`im_in` into scratch, run every
    /// stage, store into `out_re`/`out_im`. All slices must have length
    /// `self.n()`. Steady-state this performs zero heap allocation: the
    /// scratch planes are grown once and reused.
    pub fn run_row<T: PlanScalar>(
        &self,
        dir: Direction,
        re_in: &[T],
        im_in: &[T],
        out_re: &mut [T],
        out_im: &mut [T],
        scratch: &mut FftScratch,
    ) {
        let n = self.n;
        assert_eq!(re_in.len(), n, "re input length");
        assert_eq!(im_in.len(), n, "im input length");
        assert_eq!(out_re.len(), n, "re output length");
        assert_eq!(out_im.len(), n, "im output length");
        if let Some(bl) = &self.bluestein {
            bl.run_row(dir, re_in, im_in, out_re, out_im, scratch);
            return;
        }
        scratch.ensure(n);
        {
            let (a_re, a_im, _, _) = scratch.planes(n);
            for (dst, src) in a_re.iter_mut().zip(re_in) {
                *dst = src.to_f64();
            }
            for (dst, src) in a_im.iter_mut().zip(im_in) {
                *dst = src.to_f64();
            }
        }
        let in_a = self.run_loaded(dir, scratch);
        let (a_re, a_im, b_re, b_im) = scratch.planes(n);
        let (res_re, res_im): (&[f64], &[f64]) = if in_a { (a_re, a_im) } else { (b_re, b_im) };
        for (dst, src) in out_re.iter_mut().zip(res_re) {
            *dst = T::from_f64(*src);
        }
        for (dst, src) in out_im.iter_mut().zip(res_im) {
            *dst = T::from_f64(*src);
        }
    }

    /// Transform `rows` consecutive rows serially with one scratch.
    /// `re`/`im` and the outputs are row-major `rows × n`.
    #[allow(clippy::too_many_arguments)]
    pub fn run_rows_serial<T: PlanScalar>(
        &self,
        dir: Direction,
        re: &[T],
        im: &[T],
        rows: usize,
        out_re: &mut [T],
        out_im: &mut [T],
        scratch: &mut FftScratch,
    ) {
        let n = self.n;
        assert!(re.len() >= rows * n && im.len() >= rows * n, "input planes too short");
        assert!(out_re.len() >= rows * n && out_im.len() >= rows * n, "output planes too short");
        for r in 0..rows {
            let off = r * n;
            self.run_row(
                dir,
                &re[off..off + n],
                &im[off..off + n],
                &mut out_re[off..off + n],
                &mut out_im[off..off + n],
                scratch,
            );
        }
    }
}

impl Stage {
    /// One Stockham pass: reads `cur`, writes `nxt`. The inner loops are
    /// pure loads, multiplies and adds — no trig, no allocation.
    #[inline]
    fn pass(&self, cur_re: &[f64], cur_im: &[f64], nxt_re: &mut [f64], nxt_im: &mut [f64]) {
        match self.kernel {
            Kernel::R2 => self.pass_r2(cur_re, cur_im, nxt_re, nxt_im),
            Kernel::R3 { s3 } => self.pass_r3(s3, cur_re, cur_im, nxt_re, nxt_im),
            Kernel::R5 { c1, c2, s1, s2 } => {
                self.pass_r5(c1, c2, s1, s2, cur_re, cur_im, nxt_re, nxt_im)
            }
        }
    }

    /// Radix-2 butterfly — operation order identical to `fft_stockham`, so
    /// power-of-two plans stay bit-identical to the oracle.
    #[inline]
    fn pass_r2(&self, cur_re: &[f64], cur_im: &[f64], nxt_re: &mut [f64], nxt_im: &mut [f64]) {
        let stride = self.stride;
        let m = self.m;
        for p in 0..m {
            let wr = self.tw_re[p];
            let wi = self.tw_im[p];
            let ia = p * stride;
            let ib = (p + m) * stride;
            let io0 = 2 * p * stride;
            let io1 = io0 + stride;
            for q in 0..stride {
                let ar = cur_re[ia + q];
                let ai = cur_im[ia + q];
                let br = cur_re[ib + q];
                let bi = cur_im[ib + q];
                nxt_re[io0 + q] = ar + br;
                nxt_im[io0 + q] = ai + bi;
                let dr = ar - br;
                let di = ai - bi;
                nxt_re[io1 + q] = dr * wr - di * wi;
                nxt_im[io1 + q] = dr * wi + di * wr;
            }
        }
    }

    /// Radix-3 butterfly: y0 = a+s, y1/y2 = a - s/2 ± i·s3·d with
    /// s = b+c, d = b−c and s3 the sign-folded sqrt(3)/2.
    #[inline]
    #[allow(clippy::too_many_arguments)]
    fn pass_r3(
        &self,
        s3: f64,
        cur_re: &[f64],
        cur_im: &[f64],
        nxt_re: &mut [f64],
        nxt_im: &mut [f64],
    ) {
        let stride = self.stride;
        let m = self.m;
        for p in 0..m {
            let w1r = self.tw_re[2 * p];
            let w1i = self.tw_im[2 * p];
            let w2r = self.tw_re[2 * p + 1];
            let w2i = self.tw_im[2 * p + 1];
            let i0 = p * stride;
            let i1 = (p + m) * stride;
            let i2 = (p + 2 * m) * stride;
            let o0 = 3 * p * stride;
            let o1 = o0 + stride;
            let o2 = o1 + stride;
            for q in 0..stride {
                let ar = cur_re[i0 + q];
                let ai = cur_im[i0 + q];
                let br = cur_re[i1 + q];
                let bi = cur_im[i1 + q];
                let cr = cur_re[i2 + q];
                let ci = cur_im[i2 + q];
                let sr = br + cr;
                let si = bi + ci;
                let dr = br - cr;
                let di = bi - ci;
                nxt_re[o0 + q] = ar + sr;
                nxt_im[o0 + q] = ai + si;
                let er = ar - 0.5 * sr;
                let ei = ai - 0.5 * si;
                let fr = s3 * di;
                let fi = s3 * dr;
                let y1r = er - fr;
                let y1i = ei + fi;
                let y2r = er + fr;
                let y2i = ei - fi;
                nxt_re[o1 + q] = y1r * w1r - y1i * w1i;
                nxt_im[o1 + q] = y1r * w1i + y1i * w1r;
                nxt_re[o2 + q] = y2r * w2r - y2i * w2i;
                nxt_im[o2 + q] = y2r * w2i + y2i * w2r;
            }
        }
    }

    /// Radix-5 butterfly (standard 5-point DFT factorization with
    /// t1/t2 = a1±a4-style sums and the sign folded into s1/s2).
    #[inline]
    #[allow(clippy::too_many_arguments)]
    fn pass_r5(
        &self,
        c1: f64,
        c2: f64,
        s1: f64,
        s2: f64,
        cur_re: &[f64],
        cur_im: &[f64],
        nxt_re: &mut [f64],
        nxt_im: &mut [f64],
    ) {
        let stride = self.stride;
        let m = self.m;
        for p in 0..m {
            let tw = 4 * p;
            let i0 = p * stride;
            let i1 = (p + m) * stride;
            let i2 = (p + 2 * m) * stride;
            let i3 = (p + 3 * m) * stride;
            let i4 = (p + 4 * m) * stride;
            let o0 = 5 * p * stride;
            for q in 0..stride {
                let a0r = cur_re[i0 + q];
                let a0i = cur_im[i0 + q];
                let a1r = cur_re[i1 + q];
                let a1i = cur_im[i1 + q];
                let a2r = cur_re[i2 + q];
                let a2i = cur_im[i2 + q];
                let a3r = cur_re[i3 + q];
                let a3i = cur_im[i3 + q];
                let a4r = cur_re[i4 + q];
                let a4i = cur_im[i4 + q];
                let t1r = a1r + a4r;
                let t1i = a1i + a4i;
                let t2r = a2r + a3r;
                let t2i = a2i + a3i;
                let t3r = a1r - a4r;
                let t3i = a1i - a4i;
                let t4r = a2r - a3r;
                let t4i = a2i - a3i;
                nxt_re[o0 + q] = a0r + t1r + t2r;
                nxt_im[o0 + q] = a0i + t1i + t2i;
                let m1r = a0r + c1 * t1r + c2 * t2r;
                let m1i = a0i + c1 * t1i + c2 * t2i;
                let m2r = a0r + c2 * t1r + c1 * t2r;
                let m2i = a0i + c2 * t1i + c1 * t2i;
                let u1r = s1 * t3r + s2 * t4r;
                let u1i = s1 * t3i + s2 * t4i;
                let u2r = s2 * t3r - s1 * t4r;
                let u2i = s2 * t3i - s1 * t4i;
                // y_j = m ± i·u, then the group twiddle w_j.
                let ys = [
                    (m1r - u1i, m1i + u1r),
                    (m2r - u2i, m2i + u2r),
                    (m2r + u2i, m2i - u2r),
                    (m1r + u1i, m1i - u1r),
                ];
                for (j, (yr, yi)) in ys.into_iter().enumerate() {
                    let wr = self.tw_re[tw + j];
                    let wi = self.tw_im[tw + j];
                    let o = o0 + (j + 1) * stride;
                    nxt_re[o + q] = yr * wr - yi * wi;
                    nxt_im[o + q] = yr * wi + yi * wr;
                }
            }
        }
    }
}

/// Bluestein chirp-z state: the length-N DFT expressed as a circular
/// convolution of padded power-of-two length `m >= 2N-1`, using the
/// identity `kt = (k² + t² − (k−t)²) / 2`:
///
///   `X[k] = chirp[k] · Σ_t (x[t]·chirp[t]) · c[k−t]`,
///   `chirp[k] = expi(sign·π·k²/N)`, `c[j] = conj(chirp)[j]`.
///
/// The chirp tables and the kernel spectrum `F_m(c)` are precomputed per
/// direction at plan-build time; execution is two inner power-of-two
/// transforms plus O(m) pointwise work, all in reused scratch planes.
struct Bluestein {
    m: usize,
    inner: Arc<FftPlan>,
    fwd: BluesteinDir,
    inv: BluesteinDir,
}

struct BluesteinDir {
    chirp_re: Vec<f64>,
    chirp_im: Vec<f64>,
    kspec_re: Vec<f64>,
    kspec_im: Vec<f64>,
}

impl BluesteinDir {
    fn new(n: usize, m: usize, sign: f64, inner: &FftPlan) -> Self {
        let mut chirp_re = Vec::with_capacity(n);
        let mut chirp_im = Vec::with_capacity(n);
        for k in 0..n {
            // k² mod 2N keeps the trig argument small (expi has period 2π,
            // π·k²/N has period 2N in k²) — better accuracy for large k.
            let theta = sign * std::f64::consts::PI * ((k * k) % (2 * n)) as f64 / n as f64;
            chirp_re.push(theta.cos());
            chirp_im.push(theta.sin());
        }
        // Kernel c[j] = conj(chirp[j]) placed at lags 0, +j and −j (index
        // m−j). m >= 2N−1 keeps the two ranges disjoint.
        let mut c_re = vec![0.0f64; m];
        let mut c_im = vec![0.0f64; m];
        c_re[0] = chirp_re[0];
        c_im[0] = -chirp_im[0];
        for j in 1..n {
            c_re[j] = chirp_re[j];
            c_im[j] = -chirp_im[j];
            c_re[m - j] = chirp_re[j];
            c_im[m - j] = -chirp_im[j];
        }
        let mut kspec_re = vec![0.0f64; m];
        let mut kspec_im = vec![0.0f64; m];
        let mut s = FftScratch::new();
        inner.run_row::<f64>(
            Direction::Forward,
            &c_re,
            &c_im,
            &mut kspec_re,
            &mut kspec_im,
            &mut s,
        );
        Self {
            chirp_re,
            chirp_im,
            kspec_re,
            kspec_im,
        }
    }
}

impl Bluestein {
    fn new(n: usize) -> Self {
        let m = (2 * n - 1).next_power_of_two();
        // The inner plan is a power of two, so this never recurses deeper
        // (and plan_for is not holding its cache lock while we build).
        let inner = plan_for(m);
        let fwd = BluesteinDir::new(n, m, -1.0, &inner);
        let inv = BluesteinDir::new(n, m, 1.0, &inner);
        Self { m, inner, fwd, inv }
    }

    fn run_row<T: PlanScalar>(
        &self,
        dir: Direction,
        re_in: &[T],
        im_in: &[T],
        out_re: &mut [T],
        out_im: &mut [T],
        scratch: &mut FftScratch,
    ) {
        let n = re_in.len();
        let m = self.m;
        let d = match dir {
            Direction::Forward => &self.fwd,
            Direction::Inverse => &self.inv,
        };
        // Take the convolution bank by value so the inner run_row can
        // borrow the scratch again (a Vec move, no copy; put back below).
        let mut bank = std::mem::take(&mut scratch.conv);
        bank.ensure(m);
        for k in 0..n {
            let re = re_in[k].to_f64();
            let im = im_in[k].to_f64();
            bank.xr[k] = re * d.chirp_re[k] - im * d.chirp_im[k];
            bank.xi[k] = re * d.chirp_im[k] + im * d.chirp_re[k];
        }
        bank.xr[n..m].fill(0.0);
        bank.xi[n..m].fill(0.0);
        self.inner.run_row::<f64>(
            Direction::Forward,
            &bank.xr[..m],
            &bank.xi[..m],
            &mut bank.yr[..m],
            &mut bank.yi[..m],
            scratch,
        );
        for k in 0..m {
            let ar = bank.yr[k];
            let ai = bank.yi[k];
            bank.yr[k] = ar * d.kspec_re[k] - ai * d.kspec_im[k];
            bank.yi[k] = ar * d.kspec_im[k] + ai * d.kspec_re[k];
        }
        self.inner.run_row::<f64>(
            Direction::Inverse,
            &bank.yr[..m],
            &bank.yi[..m],
            &mut bank.xr[..m],
            &mut bank.xi[..m],
            scratch,
        );
        let inv_m = 1.0 / m as f64;
        for k in 0..n {
            let ar = bank.xr[k] * inv_m;
            let ai = bank.xi[k] * inv_m;
            out_re[k] = T::from_f64(ar * d.chirp_re[k] - ai * d.chirp_im[k]);
            out_im[k] = T::from_f64(ar * d.chirp_im[k] + ai * d.chirp_re[k]);
        }
        scratch.conv = bank;
    }
}

/// Reusable split re/im scratch planes (two ping-pong buffers). One per
/// worker/thread; grows monotonically to the largest `n` it has served and
/// never reallocates below that — callers can rely on pointer-stable
/// planes across executions of the same length.
///
/// Beyond the ping-pong pair, two side banks stage data around an inner
/// transform: `conv` for the Bluestein convolution, `pack` for the rFFT
/// pack/unpack. They are separate so an rFFT whose half-length plan is
/// itself Bluestein never aliases its own staging buffers; each bank is
/// taken by value around the inner call (a `Vec` move, no copy) so the
/// borrow checker allows re-entering the scratch.
#[derive(Default)]
pub struct FftScratch {
    a_re: Vec<f64>,
    a_im: Vec<f64>,
    b_re: Vec<f64>,
    b_im: Vec<f64>,
    conv: AuxBank,
    pack: AuxBank,
}

/// Four staging planes usable as an (x, y) complex pair.
#[derive(Default)]
struct AuxBank {
    xr: Vec<f64>,
    xi: Vec<f64>,
    yr: Vec<f64>,
    yi: Vec<f64>,
}

impl AuxBank {
    /// Grow every plane to at least `len` elements (no-op once large
    /// enough — same monotonic-growth contract as the main planes).
    fn ensure(&mut self, len: usize) {
        for v in [&mut self.xr, &mut self.xi, &mut self.yr, &mut self.yi] {
            if v.len() < len {
                v.resize(len, 0.0);
            }
        }
    }
}

impl FftScratch {
    pub fn new() -> Self {
        Self::default()
    }

    /// Grow every plane to at least `n` elements (no-op once large enough).
    pub fn ensure(&mut self, n: usize) {
        if self.a_re.len() < n {
            self.a_re.resize(n, 0.0);
            self.a_im.resize(n, 0.0);
            self.b_re.resize(n, 0.0);
            self.b_im.resize(n, 0.0);
        }
    }

    /// Current plane capacity in elements.
    pub fn capacity(&self) -> usize {
        self.a_re.len()
    }

    /// Base pointer of the first plane — lets tests assert that repeated
    /// executions reuse the same buffers instead of reallocating.
    pub fn base_ptr(&self) -> *const f64 {
        self.a_re.as_ptr()
    }

    fn planes(&mut self, n: usize) -> (&mut [f64], &mut [f64], &mut [f64], &mut [f64]) {
        (
            &mut self.a_re[..n],
            &mut self.a_im[..n],
            &mut self.b_re[..n],
            &mut self.b_im[..n],
        )
    }
}

/// Process-wide plan cache: one immutable `Arc<FftPlan>` per length, built
/// on first use. The lock guards only the map — execution never holds it.
static PLAN_CACHE: OnceLock<Mutex<HashMap<u64, Arc<FftPlan>>>> = OnceLock::new();

/// The cached plan for length `n` (any `n >= 1`), building it on first use.
/// A miss builds outside the lock (twiddle construction is O(n) trig) and
/// the entry API keeps whichever plan landed first, so concurrent
/// first-touch builds neither serialize other lengths nor diverge.
pub fn plan_for(n: usize) -> Arc<FftPlan> {
    let cache = PLAN_CACHE.get_or_init(|| Mutex::new(HashMap::new()));
    if let Some(plan) = cache.lock().unwrap().get(&(n as u64)) {
        return plan.clone();
    }
    let built = Arc::new(FftPlan::new(n));
    cache
        .lock()
        .unwrap()
        .entry(n as u64)
        .or_insert(built)
        .clone()
}

/// Process-wide scratch pool so ad-hoc callers (module `run_f32`, the
/// row-parallel workers) reuse planes instead of allocating per call.
/// Bounded so a burst of threads cannot pin memory forever.
static SCRATCH_POOL: OnceLock<Mutex<Vec<FftScratch>>> = OnceLock::new();
const SCRATCH_POOL_CAP: usize = 16;

/// Borrow a pooled scratch for the duration of `f`, returning it after.
pub fn with_scratch<R>(f: impl FnOnce(&mut FftScratch) -> R) -> R {
    let pool = SCRATCH_POOL.get_or_init(|| Mutex::new(Vec::new()));
    let mut scratch = pool.lock().unwrap().pop().unwrap_or_default();
    let r = f(&mut scratch);
    let mut guard = pool.lock().unwrap();
    if guard.len() < SCRATCH_POOL_CAP {
        guard.push(scratch);
    }
    r
}

/// Worker threads used for row-parallel execution: capped small (this is
/// a simulation backend sharing the host with card worker threads).
/// Override with `FFTSWEEP_FFT_THREADS=1` to force serial execution.
pub fn pool_threads() -> usize {
    static THREADS: OnceLock<usize> = OnceLock::new();
    *THREADS.get_or_init(|| {
        if let Ok(v) = std::env::var("FFTSWEEP_FFT_THREADS") {
            if let Ok(t) = v.trim().parse::<usize>() {
                return t.max(1);
            }
        }
        std::thread::available_parallelism()
            .map(|p| p.get())
            .unwrap_or(1)
            .min(4)
    })
}

/// Below this much work a batch runs serially — the scoped-thread spawn
/// (tens of µs per worker) would cost more than it saves. The threshold is
/// set so the standard serving batches (64×1024 and up) parallelize while
/// small/partial batches stay on the zero-spawn serial path.
const PAR_MIN_ROWS: usize = 2;
const PAR_MIN_ELEMS: usize = 1 << 16;

/// Execute `rows` independent transforms, row-parallel across scoped std
/// threads when the batch is large enough, serial otherwise. Rows are
/// independent and each runs the identical per-row code, so the parallel
/// result is bit-identical to [`FftPlan::run_rows_serial`].
///
/// Deliberate tradeoff: workers are *scoped spawns per call*, not a
/// persistent pool. A persistent pool executing borrowed row slices needs
/// lifetime-erasing `unsafe` (no rayon/crossbeam in the offline crate
/// set); scoped spawn is safe, and the `PAR_MIN_ELEMS` cutoff keeps the
/// spawn cost well under the FFT work it buys. Per-row execution itself
/// stays allocation- and trig-free either way; `FFTSWEEP_FFT_THREADS=1`
/// forces the fully spawn-free serial path.
pub fn run_rows<T: PlanScalar>(
    plan: &FftPlan,
    dir: Direction,
    re: &[T],
    im: &[T],
    rows: usize,
    out_re: &mut [T],
    out_im: &mut [T],
) {
    run_rows_impl(plan, dir, re, im, rows, out_re, out_im, pool_threads(), PAR_MIN_ELEMS);
}

#[allow(clippy::too_many_arguments)]
fn run_rows_impl<T: PlanScalar>(
    plan: &FftPlan,
    dir: Direction,
    re: &[T],
    im: &[T],
    rows: usize,
    out_re: &mut [T],
    out_im: &mut [T],
    threads: usize,
    min_elems: usize,
) {
    if rows == 0 {
        return;
    }
    let n = plan.n();
    let threads = threads.min(rows);
    if threads <= 1 || rows < PAR_MIN_ROWS || rows * n < min_elems {
        with_scratch(|s| plan.run_rows_serial(dir, re, im, rows, out_re, out_im, s));
        return;
    }
    let chunk_rows = rows.div_ceil(threads);
    std::thread::scope(|scope| {
        let chunks = out_re[..rows * n]
            .chunks_mut(chunk_rows * n)
            .zip(out_im[..rows * n].chunks_mut(chunk_rows * n))
            .enumerate();
        for (ci, (o_re, o_im)) in chunks {
            let start = ci * chunk_rows;
            let rows_here = o_re.len() / n;
            let re_chunk = &re[start * n..(start + rows_here) * n];
            let im_chunk = &im[start * n..(start + rows_here) * n];
            scope.spawn(move || {
                with_scratch(|s| {
                    plan.run_rows_serial(dir, re_chunk, im_chunk, rows_here, o_re, o_im, s)
                });
            });
        }
    });
}

/// Planned forward FFT of one `C64` row — drop-in for `dsp::fft` where the
/// caller wants plan-cache speed with the oracle's interface (and, unlike
/// the oracle, any transform length).
pub fn fft_planned(x: &[C64]) -> Vec<C64> {
    let n = x.len();
    let plan = plan_for(n);
    let re: Vec<f64> = x.iter().map(|c| c.re).collect();
    let im: Vec<f64> = x.iter().map(|c| c.im).collect();
    let mut out_re = vec![0.0f64; n];
    let mut out_im = vec![0.0f64; n];
    with_scratch(|s| plan.run_row(Direction::Forward, &re, &im, &mut out_re, &mut out_im, s));
    out_re
        .into_iter()
        .zip(out_im)
        .map(|(r, i)| C64::new(r, i))
        .collect()
}

/// Number of non-redundant output bins of an N-point real transform.
pub fn rfft_len(n: usize) -> usize {
    n / 2 + 1
}

/// A real-input FFT plan: X = rfft(x) for real x, producing the
/// `n/2 + 1` non-redundant bins (the rest are the conjugate mirror).
///
/// Even `n` packs the input into an `n/2`-point complex transform
/// (`z[k] = x[2k] + i·x[2k+1]`) and unpacks with `n/2` precomputed
/// twiddles — half the butterfly work of the complex transform. Odd `n`
/// falls back to the full complex plan with a zero imaginary plane, so
/// every length stays supported.
pub struct RfftPlan {
    n: usize,
    kind: RfftKind,
}

enum RfftKind {
    Half {
        plan: Arc<FftPlan>,
        /// Unpack twiddles: `tw[q] = expi(-π·q / (n/2))` for q in 1..n/2
        /// (slot 0 unused).
        tw_re: Vec<f64>,
        tw_im: Vec<f64>,
    },
    Full {
        plan: Arc<FftPlan>,
    },
}

impl RfftPlan {
    /// Build the plan for real-input length `n` (any `n >= 1`). Prefer
    /// [`rfft_plan_for`], which caches plans process-wide.
    pub fn new(n: usize) -> Self {
        assert!(n >= 1, "rFFT length must be >= 1");
        if n % 2 == 0 {
            let m = n / 2;
            let mut tw_re = Vec::with_capacity(m);
            let mut tw_im = Vec::with_capacity(m);
            for q in 0..m {
                let theta = -std::f64::consts::PI * q as f64 / m as f64;
                tw_re.push(theta.cos());
                tw_im.push(theta.sin());
            }
            Self {
                n,
                kind: RfftKind::Half {
                    plan: plan_for(m),
                    tw_re,
                    tw_im,
                },
            }
        } else {
            Self {
                n,
                kind: RfftKind::Full { plan: plan_for(n) },
            }
        }
    }

    pub fn n(&self) -> usize {
        self.n
    }

    /// Output bins per row (`n/2 + 1`).
    pub fn out_len(&self) -> usize {
        rfft_len(self.n)
    }

    /// Whether this plan runs through the packed half-length path.
    pub fn half_complex(&self) -> bool {
        matches!(self.kind, RfftKind::Half { .. })
    }

    /// Transform one real row into its `n/2 + 1` spectrum bins. `x` must
    /// have length `n`, the outputs length `out_len()`. Steady-state this
    /// performs zero heap allocation (scratch banks are reused).
    pub fn run_row<T: PlanScalar>(
        &self,
        x: &[T],
        out_re: &mut [T],
        out_im: &mut [T],
        scratch: &mut FftScratch,
    ) {
        let n = self.n;
        let o = self.out_len();
        assert_eq!(x.len(), n, "rfft input length");
        assert_eq!(out_re.len(), o, "rfft re output length");
        assert_eq!(out_im.len(), o, "rfft im output length");
        match &self.kind {
            RfftKind::Half { plan, tw_re, tw_im } => {
                let m = n / 2;
                let mut bank = std::mem::take(&mut scratch.pack);
                bank.ensure(m);
                for k in 0..m {
                    bank.xr[k] = x[2 * k].to_f64();
                    bank.xi[k] = x[2 * k + 1].to_f64();
                }
                plan.run_row::<f64>(
                    Direction::Forward,
                    &bank.xr[..m],
                    &bank.xi[..m],
                    &mut bank.yr[..m],
                    &mut bank.yi[..m],
                    scratch,
                );
                // Unpack: E[q] = (Z[q] + conj(Z[m−q]))/2 is the even-sample
                // spectrum, O[q] = (Z[q] − conj(Z[m−q]))/(2i) the odd one;
                // X[q] = E[q] + w_q·O[q], X[m] = E[0] − O[0]. DC and Nyquist
                // bins are exactly real for real input.
                let zr0 = bank.yr[0];
                let zi0 = bank.yi[0];
                out_re[0] = T::from_f64(zr0 + zi0);
                out_im[0] = T::from_f64(0.0);
                for q in 1..m {
                    let zr = bank.yr[q];
                    let zi = bank.yi[q];
                    let vr = bank.yr[m - q];
                    let vi = -bank.yi[m - q];
                    let er = 0.5 * (zr + vr);
                    let ei = 0.5 * (zi + vi);
                    let dr = 0.5 * (zr - vr);
                    let di = 0.5 * (zi - vi);
                    let or_ = di;
                    let oi = -dr;
                    let wr = tw_re[q];
                    let wi = tw_im[q];
                    out_re[q] = T::from_f64(er + or_ * wr - oi * wi);
                    out_im[q] = T::from_f64(ei + or_ * wi + oi * wr);
                }
                out_re[m] = T::from_f64(zr0 - zi0);
                out_im[m] = T::from_f64(0.0);
                scratch.pack = bank;
            }
            RfftKind::Full { plan } => {
                let mut bank = std::mem::take(&mut scratch.pack);
                bank.ensure(n);
                for k in 0..n {
                    bank.xr[k] = x[k].to_f64();
                    bank.xi[k] = 0.0;
                }
                plan.run_row::<f64>(
                    Direction::Forward,
                    &bank.xr[..n],
                    &bank.xi[..n],
                    &mut bank.yr[..n],
                    &mut bank.yi[..n],
                    scratch,
                );
                for k in 0..o {
                    out_re[k] = T::from_f64(bank.yr[k]);
                    out_im[k] = T::from_f64(bank.yi[k]);
                }
                scratch.pack = bank;
            }
        }
    }

    /// Transform `rows` consecutive real rows serially with one scratch.
    /// `x` is row-major `rows × n`; the outputs `rows × (n/2 + 1)`.
    pub fn run_rows_serial<T: PlanScalar>(
        &self,
        x: &[T],
        rows: usize,
        out_re: &mut [T],
        out_im: &mut [T],
        scratch: &mut FftScratch,
    ) {
        let n = self.n;
        let o = self.out_len();
        assert!(x.len() >= rows * n, "rfft input plane too short");
        assert!(
            out_re.len() >= rows * o && out_im.len() >= rows * o,
            "rfft output planes too short"
        );
        for r in 0..rows {
            self.run_row(
                &x[r * n..(r + 1) * n],
                &mut out_re[r * o..(r + 1) * o],
                &mut out_im[r * o..(r + 1) * o],
                scratch,
            );
        }
    }
}

/// Process-wide rFFT plan cache, mirroring [`plan_for`].
static RFFT_PLAN_CACHE: OnceLock<Mutex<HashMap<u64, Arc<RfftPlan>>>> = OnceLock::new();

/// The cached rFFT plan for real-input length `n`, building it on first
/// use (same first-build-wins discipline as [`plan_for`]).
pub fn rfft_plan_for(n: usize) -> Arc<RfftPlan> {
    let cache = RFFT_PLAN_CACHE.get_or_init(|| Mutex::new(HashMap::new()));
    if let Some(plan) = cache.lock().unwrap().get(&(n as u64)) {
        return plan.clone();
    }
    let built = Arc::new(RfftPlan::new(n));
    cache
        .lock()
        .unwrap()
        .entry(n as u64)
        .or_insert(built)
        .clone()
}

/// Execute `rows` independent real transforms, row-parallel when the batch
/// is large enough (same policy and bit-identity guarantee as [`run_rows`]).
pub fn run_rfft_rows<T: PlanScalar>(
    plan: &RfftPlan,
    x: &[T],
    rows: usize,
    out_re: &mut [T],
    out_im: &mut [T],
) {
    run_rfft_rows_impl(plan, x, rows, out_re, out_im, pool_threads(), PAR_MIN_ELEMS);
}

fn run_rfft_rows_impl<T: PlanScalar>(
    plan: &RfftPlan,
    x: &[T],
    rows: usize,
    out_re: &mut [T],
    out_im: &mut [T],
    threads: usize,
    min_elems: usize,
) {
    if rows == 0 {
        return;
    }
    let n = plan.n();
    let o = plan.out_len();
    let threads = threads.min(rows);
    if threads <= 1 || rows < PAR_MIN_ROWS || rows * n < min_elems {
        with_scratch(|s| plan.run_rows_serial(x, rows, out_re, out_im, s));
        return;
    }
    let chunk_rows = rows.div_ceil(threads);
    std::thread::scope(|scope| {
        let chunks = out_re[..rows * o]
            .chunks_mut(chunk_rows * o)
            .zip(out_im[..rows * o].chunks_mut(chunk_rows * o))
            .enumerate();
        for (ci, (o_re, o_im)) in chunks {
            let start = ci * chunk_rows;
            let rows_here = o_re.len() / o;
            let x_chunk = &x[start * n..(start + rows_here) * n];
            scope.spawn(move || {
                with_scratch(|s| plan.run_rows_serial(x_chunk, rows_here, o_re, o_im, s));
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsp::fft::{dft_naive, fft};
    use crate::util::rng::Rng;

    fn rand_row(n: usize, seed: u64) -> (Vec<f64>, Vec<f64>) {
        let mut r = Rng::new(seed);
        (
            (0..n).map(|_| r.gauss()).collect(),
            (0..n).map(|_| r.gauss()).collect(),
        )
    }

    #[test]
    fn plan_matches_naive_dft_all_lengths() {
        // The issue's acceptance grid: every power of two in 2..=4096.
        let mut n = 2usize;
        while n <= 4096 {
            let (re, im) = rand_row(n, n as u64);
            let x: Vec<C64> = re
                .iter()
                .zip(&im)
                .map(|(&r, &i)| C64::new(r, i))
                .collect();
            let want = dft_naive(&x);
            let plan = plan_for(n);
            let mut out_re = vec![0.0f64; n];
            let mut out_im = vec![0.0f64; n];
            let mut s = FftScratch::new();
            plan.run_row(Direction::Forward, &re, &im, &mut out_re, &mut out_im, &mut s);
            let tol = 1e-8 * n as f64;
            for i in 0..n {
                assert!(
                    (out_re[i] - want[i].re).abs() < tol && (out_im[i] - want[i].im).abs() < tol,
                    "n={n} bin {i}: ({}, {}) vs {:?}",
                    out_re[i],
                    out_im[i],
                    want[i]
                );
            }
            n *= 2;
        }
    }

    #[test]
    fn plan_is_bit_identical_to_stockham_oracle() {
        for n in [2usize, 8, 64, 1024] {
            let (re, im) = rand_row(n, 7 + n as u64);
            let x: Vec<C64> = re.iter().zip(&im).map(|(&r, &i)| C64::new(r, i)).collect();
            let want = fft(&x);
            let got = fft_planned(&x);
            for i in 0..n {
                assert_eq!(got[i].re.to_bits(), want[i].re.to_bits(), "n={n} bin {i} re");
                assert_eq!(got[i].im.to_bits(), want[i].im.to_bits(), "n={n} bin {i} im");
            }
        }
    }

    #[test]
    fn inverse_roundtrips() {
        let n = 256usize;
        let (re, im) = rand_row(n, 13);
        let plan = plan_for(n);
        let mut s = FftScratch::new();
        let (mut fr, mut fi) = (vec![0.0; n], vec![0.0; n]);
        plan.run_row(Direction::Forward, &re, &im, &mut fr, &mut fi, &mut s);
        let (mut br, mut bi) = (vec![0.0; n], vec![0.0; n]);
        plan.run_row(Direction::Inverse, &fr, &fi, &mut br, &mut bi, &mut s);
        for i in 0..n {
            assert!((br[i] / n as f64 - re[i]).abs() < 1e-10, "bin {i}");
            assert!((bi[i] / n as f64 - im[i]).abs() < 1e-10, "bin {i}");
        }
    }

    #[test]
    fn plan_cache_returns_the_same_arc() {
        let a = plan_for(512);
        let b = plan_for(512);
        assert!(Arc::ptr_eq(&a, &b), "cache hit must return the cached plan");
        let c = plan_for(1024);
        assert!(!Arc::ptr_eq(&a, &c));
    }

    #[test]
    fn scratch_is_pointer_stable_across_executions() {
        // The no-alloc acceptance check: run the scratch path twice (and
        // then at a smaller n) and assert the planes were not reallocated.
        let n = 1024usize;
        let plan = plan_for(n);
        let (re, im) = rand_row(n, 3);
        let (mut or1, mut oi1) = (vec![0.0; n], vec![0.0; n]);
        let mut s = FftScratch::new();
        plan.run_row(Direction::Forward, &re, &im, &mut or1, &mut oi1, &mut s);
        let ptr = s.base_ptr();
        let cap = s.capacity();
        plan.run_row(Direction::Forward, &re, &im, &mut or1, &mut oi1, &mut s);
        assert_eq!(s.base_ptr(), ptr, "second run must reuse the same planes");
        assert_eq!(s.capacity(), cap);
        // Smaller transform through the same scratch: still no realloc.
        let small = plan_for(64);
        let (sre, sim_) = rand_row(64, 4);
        let (mut sor, mut soi) = (vec![0.0; 64], vec![0.0; 64]);
        small.run_row(Direction::Forward, &sre, &sim_, &mut sor, &mut soi, &mut s);
        assert_eq!(s.base_ptr(), ptr, "smaller n must not shrink/realloc");
    }

    #[test]
    fn scratch_reuse_across_differing_batch_occupancies() {
        // One scratch serving batches of different row counts (the partial
        // vs full PackedBatch case) stays correct and allocation-stable.
        let n = 256usize;
        let plan = plan_for(n);
        let mut s = FftScratch::new();
        for rows in [1usize, 3, 8, 2, 8] {
            let (re, im) = rand_row(rows * n, rows as u64);
            let re32: Vec<f32> = re.iter().map(|&v| v as f32).collect();
            let im32: Vec<f32> = im.iter().map(|&v| v as f32).collect();
            let mut or_ = vec![0.0f32; rows * n];
            let mut oi = vec![0.0f32; rows * n];
            plan.run_rows_serial(Direction::Forward, &re32, &im32, rows, &mut or_, &mut oi, &mut s);
            for r in 0..rows {
                let off = r * n;
                let x: Vec<C64> = (0..n)
                    .map(|i| C64::new(re32[off + i] as f64, im32[off + i] as f64))
                    .collect();
                let want = fft(&x);
                for i in 0..n {
                    assert!(
                        (or_[off + i] as f64 - want[i].re).abs() < 1e-2,
                        "rows={rows} r={r} bin {i}"
                    );
                }
            }
        }
    }

    #[test]
    fn prop_row_parallel_is_bit_identical_to_serial() {
        crate::util::prop::check(
            "planner row-parallel == serial",
            |rng| {
                let n = 1usize << rng.range_u64(3, 10); // 8..=1024
                let rows = rng.range_u64(1, 40) as usize;
                let seed = rng.range_u64(0, 1 << 32);
                (n, rows, seed)
            },
            |&(n, rows, seed)| {
                let plan = plan_for(n);
                let mut r = Rng::new(seed);
                let re: Vec<f32> = (0..rows * n).map(|_| r.gauss() as f32).collect();
                let im: Vec<f32> = (0..rows * n).map(|_| r.gauss() as f32).collect();
                let mut ser_re = vec![0.0f32; rows * n];
                let mut ser_im = vec![0.0f32; rows * n];
                let mut s = FftScratch::new();
                plan.run_rows_serial(
                    Direction::Forward,
                    &re,
                    &im,
                    rows,
                    &mut ser_re,
                    &mut ser_im,
                    &mut s,
                );
                let mut par_re = vec![0.0f32; rows * n];
                let mut par_im = vec![0.0f32; rows * n];
                // min_elems = 0 forces the scoped-thread path even for the
                // small cases the generator produces.
                run_rows_impl(
                    &plan,
                    Direction::Forward,
                    &re,
                    &im,
                    rows,
                    &mut par_re,
                    &mut par_im,
                    4,
                    0,
                );
                for i in 0..rows * n {
                    if ser_re[i].to_bits() != par_re[i].to_bits()
                        || ser_im[i].to_bits() != par_im[i].to_bits()
                    {
                        return Err(format!(
                            "n={n} rows={rows} elem {i}: serial ({}, {}) vs parallel ({}, {})",
                            ser_re[i], ser_im[i], par_re[i], par_im[i]
                        ));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn f64_rows_match_oracle() {
        let n = 512usize;
        let rows = 4usize;
        let (re, im) = rand_row(rows * n, 21);
        let plan = plan_for(n);
        let mut out_re = vec![0.0f64; rows * n];
        let mut out_im = vec![0.0f64; rows * n];
        run_rows(&plan, Direction::Forward, &re, &im, rows, &mut out_re, &mut out_im);
        for row in 0..rows {
            let off = row * n;
            let x: Vec<C64> = (0..n).map(|i| C64::new(re[off + i], im[off + i])).collect();
            let want = fft(&x);
            for i in 0..n {
                assert_eq!(out_re[off + i].to_bits(), want[i].re.to_bits(), "r{row} b{i}");
                assert_eq!(out_im[off + i].to_bits(), want[i].im.to_bits(), "r{row} b{i}");
            }
        }
    }

    #[test]
    fn length_one_plan_copies() {
        let plan = plan_for(1);
        let mut s = FftScratch::new();
        let (mut or_, mut oi) = (vec![0.0f64], vec![0.0f64]);
        plan.run_row(Direction::Forward, &[2.5], &[-1.5], &mut or_, &mut oi, &mut s);
        assert_eq!(or_[0], 2.5);
        assert_eq!(oi[0], -1.5);
    }

    /// Tolerance-check one planned forward transform against the naive DFT.
    fn check_against_naive(n: usize) {
        let (re, im) = rand_row(n, 0xC0FFEE ^ n as u64);
        let x: Vec<C64> = re.iter().zip(&im).map(|(&r, &i)| C64::new(r, i)).collect();
        let want = dft_naive(&x);
        let got = fft_planned(&x);
        let tol = 1e-8 * n as f64;
        for i in 0..n {
            assert!(
                (got[i].re - want[i].re).abs() < tol && (got[i].im - want[i].im).abs() < tol,
                "n={n} bin {i}: ({}, {}) vs {:?}",
                got[i].re,
                got[i].im,
                want[i]
            );
        }
    }

    #[test]
    fn every_length_2_to_128_matches_naive_dft() {
        // Exhaustive bottom of the acceptance grid: all small lengths,
        // covering every factor-class transition (pow2, 2^a·3^b·5^c, primes,
        // prime squares, odd composites).
        for n in 2..=128usize {
            check_against_naive(n);
        }
    }

    #[test]
    fn every_length_129_to_320_matches_naive_dft() {
        for n in 129..=320usize {
            check_against_naive(n);
        }
    }

    #[test]
    fn targeted_large_lengths_match_naive_dft() {
        // The acceptance grid's upper reach, one representative per factor
        // class: primes (331, 2017, 4093), prime-square-adjacent odd smooth
        // (729, 2187, 3125), the issue's serving lengths (1000, 1536), a
        // 7-smooth Bluestein composite (4095 = 3²·5·7·13) and pow2 4096.
        let lengths = [
            331usize, 500, 625, 729, 1000, 1009, 1536, 2017, 2187, 3125, 4093, 4095, 4096,
        ];
        for n in lengths {
            check_against_naive(n);
        }
    }

    #[test]
    fn sampled_grid_2_to_4096_roundtrips_and_spot_checks() {
        // The rest of the 2..=4096 grid, sampled with a prime stride so no
        // factor class is systematically skipped. Two cheap checks per
        // length: forward→inverse/N roundtrip (O(n log n)) and the DC bin
        // against the direct sum (catches permutation/twiddle errors the
        // roundtrip alone could mask).
        let mut n = 321usize;
        while n <= 4096 {
            let (re, im) = rand_row(n, n as u64);
            let plan = plan_for(n);
            let mut s = FftScratch::new();
            let (mut fr, mut fi) = (vec![0.0f64; n], vec![0.0f64; n]);
            plan.run_row(Direction::Forward, &re, &im, &mut fr, &mut fi, &mut s);
            let dc_re: f64 = re.iter().sum();
            let dc_im: f64 = im.iter().sum();
            let tol = 1e-8 * n as f64;
            assert!(
                (fr[0] - dc_re).abs() < tol && (fi[0] - dc_im).abs() < tol,
                "n={n}: DC bin ({}, {}) vs ({dc_re}, {dc_im})",
                fr[0],
                fi[0]
            );
            let (mut br, mut bi) = (vec![0.0f64; n], vec![0.0f64; n]);
            plan.run_row(Direction::Inverse, &fr, &fi, &mut br, &mut bi, &mut s);
            for i in 0..n {
                assert!(
                    (br[i] / n as f64 - re[i]).abs() < 1e-7
                        && (bi[i] / n as f64 - im[i]).abs() < 1e-7,
                    "n={n} roundtrip bin {i}"
                );
            }
            n += 29;
        }
    }

    #[test]
    fn algorithm_classification() {
        assert_eq!(plan_for(4096).algorithm(), PlanAlgorithm::MixedRadix);
        assert_eq!(plan_for(1000).algorithm(), PlanAlgorithm::MixedRadix); // 2³·5³
        assert_eq!(plan_for(1536).algorithm(), PlanAlgorithm::MixedRadix); // 2⁹·3
        assert_eq!(plan_for(1009).algorithm(), PlanAlgorithm::Bluestein); // prime
        assert_eq!(plan_for(19321).algorithm(), PlanAlgorithm::Bluestein); // 139²
        assert_eq!(plan_for(4095).algorithm(), PlanAlgorithm::Bluestein); // 7·13 factors
        assert!(supports(1) && supports(1009));
        assert!(!supports(0));
    }

    #[test]
    fn prop_mixed_radix_row_parallel_is_bit_identical_to_serial() {
        // The non-pow2 sibling of the pow2 property test: lengths drawn
        // from every plan class (mixed radix and Bluestein).
        let menu = [12usize, 60, 100, 144, 243, 251, 360, 625, 1000, 1536];
        crate::util::prop::for_all(
            crate::util::prop::PropConfig { cases: 48, seed: 0x0FF6 },
            "planner mixed-radix row-parallel == serial",
            |rng| {
                let n = menu[rng.below(menu.len() as u64) as usize];
                let rows = rng.range_u64(1, 12) as usize;
                let seed = rng.range_u64(0, 1 << 32);
                (n, rows, seed)
            },
            |&(n, rows, seed)| {
                let plan = plan_for(n);
                let mut r = Rng::new(seed);
                let re: Vec<f32> = (0..rows * n).map(|_| r.gauss() as f32).collect();
                let im: Vec<f32> = (0..rows * n).map(|_| r.gauss() as f32).collect();
                let mut ser_re = vec![0.0f32; rows * n];
                let mut ser_im = vec![0.0f32; rows * n];
                let mut s = FftScratch::new();
                plan.run_rows_serial(
                    Direction::Forward,
                    &re,
                    &im,
                    rows,
                    &mut ser_re,
                    &mut ser_im,
                    &mut s,
                );
                let mut par_re = vec![0.0f32; rows * n];
                let mut par_im = vec![0.0f32; rows * n];
                run_rows_impl(
                    &plan,
                    Direction::Forward,
                    &re,
                    &im,
                    rows,
                    &mut par_re,
                    &mut par_im,
                    4,
                    0,
                );
                for i in 0..rows * n {
                    if ser_re[i].to_bits() != par_re[i].to_bits()
                        || ser_im[i].to_bits() != par_im[i].to_bits()
                    {
                        return Err(format!("n={n} rows={rows} elem {i} diverged"));
                    }
                }
                Ok(())
            },
        );
    }

    /// rFFT vs the complex plan on the same real signal.
    fn check_rfft(n: usize) {
        let (xs, _) = rand_row(n, 0x5EED ^ n as u64);
        let x: Vec<C64> = xs.iter().map(|&r| C64::new(r, 0.0)).collect();
        let want = fft_planned(&x);
        let rplan = rfft_plan_for(n);
        let o = rplan.out_len();
        let mut out_re = vec![0.0f64; o];
        let mut out_im = vec![0.0f64; o];
        let mut s = FftScratch::new();
        rplan.run_row(&xs, &mut out_re, &mut out_im, &mut s);
        let tol = 1e-8 * n as f64;
        for k in 0..o {
            assert!(
                (out_re[k] - want[k].re).abs() < tol && (out_im[k] - want[k].im).abs() < tol,
                "n={n} bin {k}: ({}, {}) vs {:?}",
                out_re[k],
                out_im[k],
                want[k]
            );
        }
    }

    #[test]
    fn rfft_matches_complex_reference() {
        // Even lengths run the packed half-complex path (2018 = 2·1009
        // exercises a Bluestein half-plan); odd lengths the full fallback.
        for n in [2usize, 4, 16, 100, 256, 1000, 1536, 2018, 4096] {
            assert!(rfft_plan_for(n).half_complex(), "n={n} should pack");
            check_rfft(n);
        }
        for n in [1usize, 3, 15, 81, 1009] {
            assert!(!rfft_plan_for(n).half_complex(), "n={n} is odd");
            check_rfft(n);
        }
    }

    #[test]
    fn rfft_dc_and_nyquist_bins_are_exactly_real() {
        let n = 1024usize;
        let (xs, _) = rand_row(n, 77);
        let rplan = rfft_plan_for(n);
        let o = rplan.out_len();
        let (mut or_, mut oi) = (vec![0.0f64; o], vec![0.0f64; o]);
        let mut s = FftScratch::new();
        rplan.run_row(&xs, &mut or_, &mut oi, &mut s);
        assert_eq!(oi[0], 0.0, "DC bin must be exactly real");
        assert_eq!(oi[n / 2], 0.0, "Nyquist bin must be exactly real");
        let dc: f64 = xs.iter().sum();
        assert!((or_[0] - dc).abs() < 1e-9 * n as f64);
    }

    #[test]
    fn rfft_rows_parallel_matches_serial() {
        let n = 1000usize;
        let rows = 8usize;
        let rplan = rfft_plan_for(n);
        let o = rplan.out_len();
        let mut r = Rng::new(31);
        let x: Vec<f32> = (0..rows * n).map(|_| r.gauss() as f32).collect();
        let mut ser_re = vec![0.0f32; rows * o];
        let mut ser_im = vec![0.0f32; rows * o];
        let mut s = FftScratch::new();
        rplan.run_rows_serial(&x, rows, &mut ser_re, &mut ser_im, &mut s);
        let mut par_re = vec![0.0f32; rows * o];
        let mut par_im = vec![0.0f32; rows * o];
        // min_elems = 0 forces the scoped-thread path.
        run_rfft_rows_impl(&rplan, &x, rows, &mut par_re, &mut par_im, 4, 0);
        assert_eq!(ser_re, par_re);
        assert_eq!(ser_im, par_im);
    }

    #[test]
    fn rfft_cache_returns_the_same_arc() {
        let a = rfft_plan_for(640);
        let b = rfft_plan_for(640);
        assert!(Arc::ptr_eq(&a, &b));
    }

    #[test]
    fn bluestein_reuses_scratch_without_reallocating() {
        // The no-alloc contract extends to the Bluestein convolution bank:
        // after the first run through one scratch, repeats are stable.
        let n = 1009usize;
        let plan = plan_for(n);
        let (re, im) = rand_row(n, 4);
        let (mut or_, mut oi) = (vec![0.0f64; n], vec![0.0f64; n]);
        let mut s = FftScratch::new();
        plan.run_row(Direction::Forward, &re, &im, &mut or_, &mut oi, &mut s);
        let ptr = s.conv.xr.as_ptr();
        let cap = s.conv.xr.len();
        plan.run_row(Direction::Forward, &re, &im, &mut or_, &mut oi, &mut s);
        assert_eq!(s.conv.xr.as_ptr(), ptr, "conv bank must be reused");
        assert_eq!(s.conv.xr.len(), cap);
    }

    #[test]
    #[should_panic(expected = ">= 1")]
    fn rejects_zero_length() {
        FftPlan::new(0);
    }
}
