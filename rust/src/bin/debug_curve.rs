use fftsweep::sim::{run_batch, gpu::{tesla_v100, jetson_nano, tesla_p4}};
use fftsweep::sim::freq_table::freq_table;
use fftsweep::types::{FftWorkload, Precision};
fn main() {
    for g in [tesla_v100(), jetson_nano(), tesla_p4()] {
    println!("== {}", g.name);
    for n in [1024u64] {
        let w = FftWorkload::new(n, Precision::Fp32, g.working_set_bytes);
        println!("N={n}");
        for f in freq_table(&g).stride(12) {
            let r = run_batch(&g, &w, f);
            println!("  f={f:7.1}  t={:8.3} ms  P={:7.1} W  E={:8.2} J", r.timing.total_s*1e3, r.avg_power_w, r.energy_j);
        }
    }
    }
}
