use fftsweep::runtime::{Manifest, Runtime};
fn main() -> anyhow::Result<()> {
    let rt = Runtime::new(&Manifest::default_dir())?;
    let m = rt.load("fft_f32_n16384_b4")?;
    let n = 16384usize; let b = 4usize;
    let mut re = vec![0.0f32; b*n];
    let im = vec![0.0f32; b*n];
    for row in 0..b { re[row*n + 1] = 1.0; }
    let out = m.run_f32(&[&re, &im])?;
    let mut max_err = 0.0f64;
    for k in 0..n {
        let want = (-2.0*std::f64::consts::PI*(k as f64)/n as f64).cos();
        max_err = max_err.max((out[0][k] as f64 - want).abs());
    }
    println!("artifact err vs analytic: {max_err:.3e}");
    let s0: f64 = out[0].iter().map(|x| x.abs() as f64).sum();
    let s1: f64 = out[1].iter().map(|x| x.abs() as f64).sum();
    println!("sum|re|={s0:.3} sum|im|={s1:.3} len={} {}", out[0].len(), out[1].len());
    println!("first 4 outputs: {:?} want cos: {:?}", &out[0][0..4], (0..4).map(|k| (-2.0*std::f64::consts::PI*(k as f64)/n as f64).cos()).collect::<Vec<_>>());
    Ok(())
}
