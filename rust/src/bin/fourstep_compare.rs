//! Four-step vs monolithic plan comparison at large N.
//!
//! Replaces the old `debug_fourstep` sketch: instead of poking an FFT
//! artifact, this drives the real planner paths — the cache-blocked
//! four-step decomposition against a monolithic mixed-radix plan of the
//! same length — and reports numeric agreement, pass counts, twiddle
//! footprints and wall-clock rows/s for both.
//!
//!   cargo run --release --bin fourstep_compare -- [--n 262144] [--rows 4] [--reps 3]
//!
//! The default length sits past the four-step threshold, so `plan_for`
//! would pick four-step on its own; both plans here are forced explicitly
//! so the comparison is independent of the `FFTSWEEP_FFT_FOURSTEP` knob.

use std::time::Instant;

use anyhow::{ensure, Context, Result};

use fftsweep::dsp::{run_rows, Direction, FftPlan};
use fftsweep::util::cliargs::Args;
use fftsweep::util::rng::Rng;

fn time_rows(plan: &FftPlan, re: &[f32], im: &[f32], rows: usize, reps: usize) -> (f64, Vec<f32>, Vec<f32>) {
    let mut out_re = vec![0.0f32; re.len()];
    let mut out_im = vec![0.0f32; im.len()];
    // One untimed pass warms the pooled scratch banks and twiddle narrowing.
    run_rows(plan, Direction::Forward, re, im, rows, &mut out_re, &mut out_im);
    let t0 = Instant::now();
    for _ in 0..reps {
        run_rows(plan, Direction::Forward, re, im, rows, &mut out_re, &mut out_im);
    }
    let dt = t0.elapsed().as_secs_f64();
    ((reps * rows) as f64 / dt.max(1e-12), out_re, out_im)
}

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let n = args.u64_or("n", 1 << 18) as usize;
    let rows = args.usize_or("rows", 4).max(1);
    let reps = args.usize_or("reps", 3).max(1);

    let four = FftPlan::new_four_step(n)
        .with_context(|| format!("n={n} has no four-step split (needs a 2/3/5-smooth composite)"))?;
    let mono = FftPlan::new_monolithic(n);
    let (n1, n2) = four.four_step_split().expect("forced four-step plan");
    println!("N = {n} = {n1} x {n2}, {rows} row(s), {reps} rep(s)");
    println!(
        "  monolithic: {:>2} passes, {:>10} twiddle bytes",
        mono.pass_count(),
        mono.twiddle_bytes()
    );
    println!(
        "  four-step:  {:>2} passes, {:>10} twiddle bytes (split tables, L2-resident sub-plans)",
        four.pass_count(),
        four.twiddle_bytes()
    );

    let mut rng = Rng::new(0xF0C5);
    let re: Vec<f32> = (0..rows * n).map(|_| rng.gauss() as f32).collect();
    let im: Vec<f32> = (0..rows * n).map(|_| rng.gauss() as f32).collect();

    let (mono_rps, mre, mim) = time_rows(&mono, &re, &im, rows, reps);
    let (four_rps, fre, fim) = time_rows(&four, &re, &im, rows, reps);

    // Numeric agreement: relative L2 between the two schedules.
    let (mut num, mut den) = (0.0f64, 0.0f64);
    for i in 0..rows * n {
        let dr = fre[i] as f64 - mre[i] as f64;
        let di = fim[i] as f64 - mim[i] as f64;
        num += dr * dr + di * di;
        den += (mre[i] as f64).powi(2) + (mim[i] as f64).powi(2);
    }
    let rel = (num / den.max(1e-300)).sqrt();
    println!("  rel L2 four-step vs monolithic: {rel:.3e}");
    println!("  monolithic: {mono_rps:>10.2} rows/s");
    println!("  four-step:  {four_rps:>10.2} rows/s ({:.2}x)", four_rps / mono_rps.max(1e-12));
    ensure!(rel < 1e-5, "schedules disagree: rel L2 {rel:.3e}");
    Ok(())
}
