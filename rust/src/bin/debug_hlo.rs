// Load an arbitrary HLO text file, run with synthetic inputs, print stats.
use anyhow::Result;
fn main() -> Result<()> {
    let path = std::env::args().nth(1).unwrap();
    let shapes: Vec<Vec<i64>> = std::env::args().skip(2).map(|s|
        s.split('x').map(|d| d.parse().unwrap()).collect()).collect();
    let client = xla::PjRtClient::cpu()?;
    let proto = xla::HloModuleProto::from_text_file(&path)?;
    let comp = xla::XlaComputation::from_proto(&proto);
    let exe = client.compile(&comp)?;
    let mut lits = Vec::new();
    for dims in &shapes {
        let total: i64 = dims.iter().product();
        let data: Vec<f32> = (0..total).map(|i| ((i % 7) as f32) * 0.25 - 0.5).collect();
        lits.push(xla::Literal::vec1(&data).reshape(dims)?);
    }
    let result = exe.execute::<xla::Literal>(&lits)?[0][0].to_literal_sync()?;
    let parts = result.to_tuple()?;
    for (i, p) in parts.into_iter().enumerate() {
        let v = p.to_vec::<f32>()?;
        let sum: f64 = v.iter().map(|x| x.abs() as f64).sum();
        println!("out{i}: len={} sum|x|={sum:.4} head={:?}", v.len(), &v[..4.min(v.len())]);
    }
    Ok(())
}
