//! PJRT runtime client: loads HLO-text artifacts, compiles them once on the
//! CPU PJRT client, and executes them from the rust hot path.
//!
//! Interchange is HLO *text* (see /opt/xla-example/README.md): jax >= 0.5
//! emits HloModuleProto with 64-bit ids that xla_extension 0.5.1 rejects;
//! the text parser reassigns ids.

use std::collections::HashMap;
use std::path::Path;
use std::sync::Mutex;

use anyhow::{Context, Result};

use super::artifact::{ArtifactMeta, Manifest};

/// A compiled artifact plus its metadata.
pub struct LoadedModule {
    pub meta: ArtifactMeta,
    exe: xla::PjRtLoadedExecutable,
}

impl LoadedModule {
    /// Execute with f32 input planes, returning the flattened f32 outputs.
    /// Input/outputs are row-major (batch, n).
    pub fn run_f32(&self, inputs: &[&[f32]]) -> Result<Vec<Vec<f32>>> {
        let literals = self.literals_f32(inputs)?;
        self.run_literals(&literals)
    }

    /// Build input literals (exposed so benches can split setup from run).
    pub fn literals_f32(&self, inputs: &[&[f32]]) -> Result<Vec<xla::Literal>> {
        let shapes = self.meta.input_shapes();
        anyhow::ensure!(
            inputs.len() == shapes.len(),
            "artifact {} wants {} inputs, got {}",
            self.meta.name,
            shapes.len(),
            inputs.len()
        );
        let mut literals = Vec::with_capacity(inputs.len());
        for (data, (_ty, dims)) in inputs.iter().zip(&shapes) {
            let want: u64 = dims.iter().product();
            anyhow::ensure!(
                want == data.len() as u64,
                "artifact {} input wants {} elements, got {}",
                self.meta.name,
                want,
                data.len()
            );
            let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
            literals.push(xla::Literal::vec1(data).reshape(&dims_i64)?);
        }
        Ok(literals)
    }

    /// Execute pre-built literals.
    pub fn run_literals(&self, literals: &[xla::Literal]) -> Result<Vec<Vec<f32>>> {
        let result = self.exe.execute::<xla::Literal>(literals)?[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True: always a tuple.
        let parts = result.to_tuple()?;
        anyhow::ensure!(
            parts.len() == self.meta.n_outputs,
            "artifact {}: {} outputs, manifest says {}",
            self.meta.name,
            parts.len(),
            self.meta.n_outputs
        );
        parts
            .into_iter()
            .map(|p| {
                let p = if p.ty()? == xla::ElementType::F32 {
                    p
                } else {
                    p.convert(xla::PrimitiveType::F32)?
                };
                Ok(p.to_vec::<f32>()?)
            })
            .collect()
    }

    /// Serving path for `fft` artifacts writing into caller-owned output
    /// planes (API parity with the sim backend's zero-copy native-f32
    /// path; PJRT executes f32 artifacts natively on device already, and
    /// returns owned literals, so this copies once into the buffers).
    pub fn run_fft_f32_into(
        &self,
        re: &[f32],
        im: &[f32],
        out_re: &mut Vec<f32>,
        out_im: &mut Vec<f32>,
    ) -> Result<()> {
        anyhow::ensure!(
            self.meta.kind == "fft",
            "run_fft_f32_into on '{}' (kind {})",
            self.meta.name,
            self.meta.kind
        );
        let outputs = self.run_f32(&[re, im])?;
        out_re.clear();
        out_re.extend_from_slice(&outputs[0]);
        out_im.clear();
        out_im.extend_from_slice(&outputs[1]);
        Ok(())
    }

    /// Serving path for `rfft` artifacts writing into caller-owned output
    /// planes (API parity with the sim backend's real-input path: one
    /// (batch, n) real plane in, two (batch, n/2+1) spectrum planes out).
    pub fn run_rfft_f32_into(
        &self,
        x: &[f32],
        out_re: &mut Vec<f32>,
        out_im: &mut Vec<f32>,
    ) -> Result<()> {
        anyhow::ensure!(
            self.meta.kind == "rfft",
            "run_rfft_f32_into on '{}' (kind {})",
            self.meta.name,
            self.meta.kind
        );
        let outputs = self.run_f32(&[x])?;
        out_re.clear();
        out_re.extend_from_slice(&outputs[0]);
        out_im.clear();
        out_im.extend_from_slice(&outputs[1]);
        Ok(())
    }

    /// Serving path for `conv` artifacts writing into a caller-owned
    /// output plane (API parity with the sim backend's overlap-save
    /// filterbank path: one (batch, n) real plane in, one filtered
    /// (batch, n) plane out).
    pub fn run_conv_f32_into(&self, x: &[f32], out: &mut Vec<f32>) -> Result<()> {
        anyhow::ensure!(
            self.meta.kind == "conv",
            "run_conv_f32_into on '{}' (kind {})",
            self.meta.name,
            self.meta.kind
        );
        let outputs = self.run_f32(&[x])?;
        out.clear();
        out.extend_from_slice(&outputs[0]);
        Ok(())
    }

    /// Execute with f64 planes (the fp64 artifacts).
    pub fn run_f64(&self, inputs: &[&[f64]]) -> Result<Vec<Vec<f64>>> {
        let shapes = self.meta.input_shapes();
        let mut literals = Vec::with_capacity(inputs.len());
        for (data, (_ty, dims)) in inputs.iter().zip(&shapes) {
            let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
            literals.push(xla::Literal::vec1(data).reshape(&dims_i64)?);
        }
        let result = self.exe.execute::<xla::Literal>(&literals)?[0][0].to_literal_sync()?;
        let parts = result.to_tuple()?;
        parts
            .into_iter()
            .map(|p| {
                let p = if p.ty()? == xla::ElementType::F64 {
                    p
                } else {
                    p.convert(xla::PrimitiveType::F64)?
                };
                Ok(p.to_vec::<f64>()?)
            })
            .collect()
    }
}

/// The runtime: one PJRT CPU client + a compile cache keyed by artifact name.
pub struct Runtime {
    client: xla::PjRtClient,
    manifest: Manifest,
    cache: Mutex<HashMap<String, std::sync::Arc<LoadedModule>>>,
}

// PJRT handles are internally synchronized for our usage pattern (compile
// once, execute from the owning thread group).
unsafe impl Send for Runtime {}
unsafe impl Sync for Runtime {}
unsafe impl Send for LoadedModule {}
unsafe impl Sync for LoadedModule {}

impl Runtime {
    /// Create against an artifact directory (reads manifest.tsv).
    pub fn new(artifact_dir: &Path) -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let manifest = Manifest::load(artifact_dir)?;
        Ok(Self {
            client,
            manifest,
            cache: Mutex::new(HashMap::new()),
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Poison-recovering cache lock — same contract as the sim backend: a
    /// panicking loader must not wedge other cards' loads (worst case a
    /// module re-compiles).
    fn cache_guard(&self) -> std::sync::MutexGuard<'_, HashMap<String, std::sync::Arc<LoadedModule>>> {
        self.cache.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
    }

    /// Load + compile an artifact (cached).
    pub fn load(&self, name: &str) -> Result<std::sync::Arc<LoadedModule>> {
        if let Some(m) = self.cache_guard().get(name) {
            return Ok(m.clone());
        }
        let meta = self.manifest.get(name)?.clone();
        // Same load-time gate as the sim backend (hoisted so the two can
        // never drift again): digest + HLO-header check before compiling.
        super::validation::check_artifact_on_load(&meta)?;
        let proto = xla::HloModuleProto::from_text_file(
            meta.file
                .to_str()
                .context("artifact path not valid UTF-8")?,
        )
        .with_context(|| format!("parsing HLO text {:?}", meta.file))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling artifact {name}"))?;
        let module = std::sync::Arc::new(LoadedModule { meta, exe });
        self.cache_guard().insert(name.to_string(), module.clone());
        Ok(module)
    }

    /// Names of all artifacts currently compiled, sorted (same contract as
    /// the sim backend: stable for logs and assertions).
    pub fn loaded_names(&self) -> Vec<String> {
        let mut names: Vec<String> = self.cache_guard().keys().cloned().collect();
        names.sort();
        names
    }
}
