//! Simulated runtime backend (default build): executes artifacts with the
//! pure-rust DSP oracle instead of PJRT, so the coordinator, CLI and tests
//! run in environments without the native XLA library or any artifacts on
//! disk. API-compatible with `client::Runtime` (the `xla`-feature backend).
//!
//! Defense-in-depth is preserved: when a manifest and HLO files DO exist
//! on disk, loads still verify the digest and the HLO-text header, so a
//! tampered artifact fails loudly here exactly as it does under PJRT.

use std::collections::HashMap;
use std::path::Path;
use std::sync::{Arc, Mutex};

use anyhow::{Context, Result};

use super::artifact::{ArtifactMeta, Manifest};
use super::validation::sha256_16;
use crate::dsp;

/// A loaded artifact plus its metadata, executed by the DSP oracle.
pub struct LoadedModule {
    pub meta: ArtifactMeta,
}

impl LoadedModule {
    /// Execute with f32 input planes, returning the flattened f32 outputs.
    /// Input/outputs are row-major (batch, n).
    pub fn run_f32(&self, inputs: &[&[f32]]) -> Result<Vec<Vec<f32>>> {
        self.check_inputs(inputs.len(), inputs.iter().map(|i| i.len()))?;
        let (re, im) = (inputs[0], inputs[1]);
        let n = self.meta.n as usize;
        let batch = self.meta.batch as usize;
        match self.meta.kind.as_str() {
            "fft" => {
                let mut out_re = Vec::with_capacity(batch * n);
                let mut out_im = Vec::with_capacity(batch * n);
                for b in 0..batch {
                    for c in row_fft(re, im, b, n) {
                        out_re.push(c.re as f32);
                        out_im.push(c.im as f32);
                    }
                }
                Ok(vec![out_re, out_im])
            }
            "spectrum" => {
                let mut power = Vec::with_capacity(batch * n);
                for b in 0..batch {
                    let x = row_fft(re, im, b, n);
                    power.extend(x.iter().map(|c| c.abs2() as f32));
                }
                Ok(vec![power])
            }
            "pipeline" => {
                let h = self.meta.harmonics as usize;
                let n_out = n / h.max(1);
                let mut hs = Vec::with_capacity(batch * n_out);
                let mut means = Vec::with_capacity(batch);
                let mut stds = Vec::with_capacity(batch);
                for b in 0..batch {
                    let x = row_fft(re, im, b, n);
                    let power: Vec<f32> = x.iter().map(|c| c.abs2() as f32).collect();
                    hs.extend(dsp::harmonic_sum(&power, h));
                    let (mean, std) = dsp::moments(&power);
                    means.push(mean);
                    stds.push(std);
                }
                Ok(vec![hs, means, stds])
            }
            other => anyhow::bail!("sim backend cannot execute kind '{other}'"),
        }
    }

    /// Build "input literals". The sim backend has no device buffers; this
    /// exists so benches exercising setup-vs-run splits still compile.
    pub fn literals_f32(&self, inputs: &[&[f32]]) -> Result<Vec<Vec<f32>>> {
        self.check_inputs(inputs.len(), inputs.iter().map(|i| i.len()))?;
        Ok(inputs.iter().map(|i| i.to_vec()).collect())
    }

    /// Execute pre-built literals.
    pub fn run_literals(&self, literals: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        let planes: Vec<&[f32]> = literals.iter().map(|l| l.as_slice()).collect();
        self.run_f32(&planes)
    }

    /// Execute with f64 planes (the fp64 artifacts).
    pub fn run_f64(&self, inputs: &[&[f64]]) -> Result<Vec<Vec<f64>>> {
        self.check_inputs(inputs.len(), inputs.iter().map(|i| i.len()))?;
        anyhow::ensure!(
            self.meta.kind == "fft",
            "sim backend only runs fft artifacts in f64"
        );
        let (re, im) = (inputs[0], inputs[1]);
        let n = self.meta.n as usize;
        let batch = self.meta.batch as usize;
        let mut out_re = Vec::with_capacity(batch * n);
        let mut out_im = Vec::with_capacity(batch * n);
        for b in 0..batch {
            let off = b * n;
            let x: Vec<dsp::C64> = (0..n)
                .map(|i| dsp::C64::new(re[off + i], im[off + i]))
                .collect();
            for c in dsp::fft(&x) {
                out_re.push(c.re);
                out_im.push(c.im);
            }
        }
        Ok(vec![out_re, out_im])
    }

    fn check_inputs(&self, got: usize, lens: impl Iterator<Item = usize>) -> Result<()> {
        let shapes = self.meta.input_shapes();
        anyhow::ensure!(
            got == shapes.len(),
            "artifact {} wants {} inputs, got {got}",
            self.meta.name,
            shapes.len()
        );
        for (len, (_ty, dims)) in lens.zip(&shapes) {
            let want: u64 = dims.iter().product();
            anyhow::ensure!(
                want == len as u64,
                "artifact {} input wants {want} elements, got {len}",
                self.meta.name
            );
        }
        Ok(())
    }
}

/// The simulated runtime: manifest (on-disk or synthetic) + a load cache.
pub struct Runtime {
    manifest: Manifest,
    cache: Mutex<HashMap<String, Arc<LoadedModule>>>,
}

impl Runtime {
    /// Create against an artifact directory. Reads `manifest.tsv` when
    /// present; otherwise synthesizes the standard artifact set so the
    /// serving stack works in a fresh checkout.
    pub fn new(artifact_dir: &Path) -> Result<Self> {
        let manifest = if artifact_dir.join("manifest.tsv").exists() {
            Manifest::load(artifact_dir)?
        } else {
            Manifest::synthetic(artifact_dir)
        };
        Ok(Self {
            manifest,
            cache: Mutex::new(HashMap::new()),
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform(&self) -> String {
        "sim-cpu (dsp oracle; build with --features xla for PJRT)".to_string()
    }

    /// Load an artifact (cached). Real on-disk artifacts are digest- and
    /// header-checked; synthetic entries load directly.
    pub fn load(&self, name: &str) -> Result<Arc<LoadedModule>> {
        if let Some(m) = self.cache.lock().unwrap().get(name) {
            return Ok(m.clone());
        }
        let meta = self.manifest.get(name)?.clone();
        if meta.digest != Manifest::SIMULATED_DIGEST {
            let text = std::fs::read_to_string(&meta.file)
                .with_context(|| format!("reading HLO text {:?}", meta.file))?;
            anyhow::ensure!(
                text.starts_with("HloModule"),
                "artifact {name}: {:?} is not HLO text",
                meta.file
            );
            let actual = sha256_16(text.as_bytes());
            anyhow::ensure!(
                actual == meta.digest,
                "artifact {name}: digest mismatch ({actual} vs manifest {})",
                meta.digest
            );
        }
        let module = Arc::new(LoadedModule { meta });
        self.cache
            .lock()
            .unwrap()
            .insert(name.to_string(), module.clone());
        Ok(module)
    }

    /// Names of all artifacts currently loaded.
    pub fn loaded_names(&self) -> Vec<String> {
        self.cache.lock().unwrap().keys().cloned().collect()
    }
}

fn row_fft(re: &[f32], im: &[f32], row: usize, n: usize) -> Vec<dsp::C64> {
    let off = row * n;
    let x: Vec<dsp::C64> = (0..n)
        .map(|i| dsp::C64::new(re[off + i] as f64, im[off + i] as f64))
        .collect();
    dsp::fft(&x)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn rt() -> Runtime {
        Runtime::new(Path::new("/nonexistent-artifacts")).unwrap()
    }

    #[test]
    fn synthetic_runtime_serves_fft() {
        let rt = rt();
        let m = rt.load("fft_f32_n256_b256").unwrap();
        let total = (m.meta.batch * m.meta.n) as usize;
        let mut rng = Rng::new(1);
        let re: Vec<f32> = (0..total).map(|_| rng.gauss() as f32).collect();
        let im: Vec<f32> = (0..total).map(|_| rng.gauss() as f32).collect();
        let out = m.run_f32(&[&re, &im]).unwrap();
        assert_eq!(out.len(), 2);
        assert_eq!(out[0].len(), total);
        // row 0 matches the oracle by construction; sanity: Parseval
        let n = m.meta.n as usize;
        let e_time: f64 = (0..n)
            .map(|i| (re[i] as f64).powi(2) + (im[i] as f64).powi(2))
            .sum();
        let e_freq: f64 = (0..n)
            .map(|i| (out[0][i] as f64).powi(2) + (out[1][i] as f64).powi(2))
            .sum::<f64>()
            / n as f64;
        assert!((e_time - e_freq).abs() < 1e-6 * e_time.max(1.0));
    }

    #[test]
    fn wrong_input_arity_or_shape_rejected() {
        let rt = rt();
        let m = rt.load("fft_f32_n256_b256").unwrap();
        let total = (m.meta.batch * m.meta.n) as usize;
        let plane = vec![0.0f32; total];
        assert!(m.run_f32(&[&plane]).is_err(), "arity");
        let short = vec![0.0f32; total - 1];
        assert!(m.run_f32(&[&short, &plane]).is_err(), "shape");
    }

    #[test]
    fn unknown_artifact_rejected() {
        let rt = rt();
        assert!(rt.load("fft_f32_n512_b1").is_err());
    }

    #[test]
    fn load_is_cached() {
        let rt = rt();
        rt.load("fft_f32_n1024_b64").unwrap();
        rt.load("fft_f32_n1024_b64").unwrap();
        assert_eq!(rt.loaded_names(), vec!["fft_f32_n1024_b64".to_string()]);
    }

    #[test]
    fn on_disk_artifacts_are_digest_checked() {
        let dir = std::env::temp_dir().join(format!("fftsweep_sim_rt_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let good = "HloModule sim_check\nENTRY main {}\n";
        std::fs::write(dir.join("good.hlo.txt"), good).unwrap();
        let digest = sha256_16(good.as_bytes());
        let manifest = format!(
            "name\tfile\tkind\tn\tbatch\tdtype\tharmonics\tinputs\tn_outputs\tsha256_16\n\
             good\tgood.hlo.txt\tfft\t8\t1\tf32\t0\tf32:1x8;f32:1x8\t2\t{digest}\n\
             tampered\tgood.hlo.txt\tfft\t8\t1\tf32\t0\tf32:1x8;f32:1x8\t2\t0000000000000000\n"
        );
        std::fs::write(dir.join("manifest.tsv"), manifest).unwrap();
        let rt = Runtime::new(&dir).unwrap();
        assert!(rt.load("good").is_ok());
        assert!(rt.load("tampered").is_err(), "digest mismatch must fail loud");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
