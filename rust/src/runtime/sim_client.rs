//! Simulated runtime backend (default build): executes artifacts with the
//! pure-rust DSP stack instead of PJRT, so the coordinator, CLI and tests
//! run in environments without the native XLA library or any artifacts on
//! disk. API-compatible with `client::Runtime` (the `xla`-feature backend).
//!
//! Execution goes through the planned engine (`dsp::planner`): cached
//! twiddle tables, reusable SoA scratch planes and batch execution
//! through the persistent worker pool — no per-row trig, allocation or
//! thread spawn, which is what makes the serving fleet's hot loop cheap.
//! f32 artifacts execute **natively in f32 planes** (the planner's
//! kernels are monomorphized per precision, twiddles pre-narrowed at
//! plan build) — no f32→f64 plane conversion and half the memory
//! traffic of the old always-f64 path. The planner's radix-2 baseline
//! schedule remains bit-identical to the `dsp::fft` oracle; the default
//! high-radix / four-step schedules it serves are tolerance-tested
//! against that baseline, and f32 output tracks the f64 path within the
//! planner's log₂N-scaled tolerance tier. `conv` artifacts filter rows
//! through the cached overlap-save plan (`dsp::planner::ConvPlan`) with
//! the standard synthetic kernel (taps carried in the manifest's
//! harmonics field).
//!
//! Defense-in-depth is preserved: when a manifest and HLO files DO exist
//! on disk, loads still verify the digest and the HLO-text header, so a
//! tampered artifact fails loudly here exactly as it does under PJRT.

use std::collections::HashMap;
use std::path::Path;
use std::sync::{Arc, RwLock};

use anyhow::Result;

use super::artifact::{ArtifactMeta, Manifest};
use super::backend::resize_for_overwrite;
use super::validation::check_artifact_on_load;
use crate::dsp;
use crate::dsp::planner::{self, Direction};

/// A loaded artifact plus its metadata, executed by the DSP oracle.
pub struct LoadedModule {
    pub meta: ArtifactMeta,
    /// The complex execution plan for `meta.n` (fft/spectrum/pipeline
    /// kinds), resolved once at load time so the serving hot path never
    /// touches the global plan-cache lock. Any length is supported: the
    /// planner compiles mixed-radix or Bluestein plans as needed.
    fft_plan: Option<std::sync::Arc<crate::dsp::planner::FftPlan>>,
    /// The real-input plan for `rfft` artifacts.
    rfft_plan: Option<std::sync::Arc<crate::dsp::planner::RfftPlan>>,
    /// The overlap-save filtering plan for `conv` artifacts (kernel =
    /// `synthetic_kernel(meta.harmonics)`, spectrum cached in the plan).
    conv_plan: Option<std::sync::Arc<crate::dsp::planner::ConvPlan>>,
}

impl LoadedModule {
    fn new(meta: ArtifactMeta) -> Self {
        let n = meta.n as usize;
        let (fft_plan, rfft_plan, conv_plan) = match meta.kind.as_str() {
            "rfft" => (None, Some(planner::rfft_plan_for(n)), None),
            "conv" => {
                let kernel = planner::synthetic_kernel((meta.harmonics as usize).max(1));
                (None, None, Some(planner::conv_plan_for(n, &kernel)))
            }
            _ => (Some(planner::plan_for(n)), None, None),
        };
        Self {
            meta,
            fft_plan,
            rfft_plan,
            conv_plan,
        }
    }

    fn plan(&self) -> std::sync::Arc<crate::dsp::planner::FftPlan> {
        match &self.fft_plan {
            Some(p) => p.clone(),
            None => planner::plan_for(self.meta.n as usize),
        }
    }

    fn rplan(&self) -> std::sync::Arc<crate::dsp::planner::RfftPlan> {
        match &self.rfft_plan {
            Some(p) => p.clone(),
            None => planner::rfft_plan_for(self.meta.n as usize),
        }
    }

    fn cplan(&self) -> std::sync::Arc<crate::dsp::planner::ConvPlan> {
        match &self.conv_plan {
            Some(p) => p.clone(),
            None => {
                let kernel = planner::synthetic_kernel((self.meta.harmonics as usize).max(1));
                planner::conv_plan_for(self.meta.n as usize, &kernel)
            }
        }
    }

    /// Execute with f32 input planes, returning the flattened f32 outputs.
    /// Input/outputs are row-major (batch, n) — except `rfft`, which takes
    /// one real plane and returns two (batch, n/2+1) spectrum planes.
    pub fn run_f32(&self, inputs: &[&[f32]]) -> Result<Vec<Vec<f32>>> {
        self.check_inputs(inputs.len(), inputs.iter().map(|i| i.len()))?;
        let n = self.meta.n as usize;
        let batch = self.meta.batch as usize;
        match self.meta.kind.as_str() {
            "fft" => {
                // Single fft execution path (inputs validated above).
                let (re, im) = (inputs[0], inputs[1]);
                let mut out_re = Vec::new();
                let mut out_im = Vec::new();
                self.exec_fft_into(re, im, &mut out_re, &mut out_im);
                Ok(vec![out_re, out_im])
            }
            "rfft" => {
                let mut out_re = Vec::new();
                let mut out_im = Vec::new();
                self.exec_rfft_into(inputs[0], &mut out_re, &mut out_im);
                Ok(vec![out_re, out_im])
            }
            "conv" => {
                let mut y = Vec::new();
                self.exec_conv_into(inputs[0], &mut y);
                Ok(vec![y])
            }
            "spectrum" => {
                let (re, im) = (inputs[0], inputs[1]);
                let plan = self.plan();
                let mut f_re = vec![0.0f32; batch * n];
                let mut f_im = vec![0.0f32; batch * n];
                planner::run_rows(&plan, Direction::Forward, re, im, batch, &mut f_re, &mut f_im);
                Ok(vec![dsp::power_spectrum(&f_re, &f_im)])
            }
            "pipeline" => {
                let (re, im) = (inputs[0], inputs[1]);
                let plan = self.plan();
                let mut f_re = vec![0.0f32; batch * n];
                let mut f_im = vec![0.0f32; batch * n];
                planner::run_rows(&plan, Direction::Forward, re, im, batch, &mut f_re, &mut f_im);
                let power = dsp::power_spectrum(&f_re, &f_im);
                let h = self.meta.harmonics as usize;
                let n_out = n / h.max(1);
                let mut hs = Vec::with_capacity(batch * n_out);
                let mut means = Vec::with_capacity(batch);
                let mut stds = Vec::with_capacity(batch);
                for b in 0..batch {
                    let row = &power[b * n..(b + 1) * n];
                    hs.extend(dsp::harmonic_sum(row, h));
                    let (mean, std) = dsp::moments(row);
                    means.push(mean);
                    stds.push(std);
                }
                Ok(vec![hs, means, stds])
            }
            other => anyhow::bail!("sim backend cannot execute kind '{other}'"),
        }
    }

    /// Zero-copy serving path for `fft` artifacts: execute straight into
    /// caller-owned output planes. The buffers are resized (never shrunk)
    /// and fully overwritten, so a worker reusing the same two `Vec`s per
    /// batch reaches a zero-allocation steady state.
    pub fn run_fft_f32_into(
        &self,
        re: &[f32],
        im: &[f32],
        out_re: &mut Vec<f32>,
        out_im: &mut Vec<f32>,
    ) -> Result<()> {
        anyhow::ensure!(
            self.meta.kind == "fft",
            "run_fft_f32_into on '{}' (kind {})",
            self.meta.name,
            self.meta.kind
        );
        self.check_inputs(2, [re.len(), im.len()].into_iter())?;
        self.exec_fft_into(re, im, out_re, out_im);
        Ok(())
    }

    /// The one fft execution body (callers have validated inputs).
    fn exec_fft_into(&self, re: &[f32], im: &[f32], out_re: &mut Vec<f32>, out_im: &mut Vec<f32>) {
        let n = self.meta.n as usize;
        let batch = self.meta.batch as usize;
        // No zero-fill: run_rows overwrites every element of both planes.
        resize_for_overwrite(out_re, batch * n);
        resize_for_overwrite(out_im, batch * n);
        let plan = self.plan();
        planner::run_rows(&plan, Direction::Forward, re, im, batch, out_re, out_im);
    }

    /// Zero-copy serving path for `rfft` artifacts, mirroring
    /// [`Self::run_fft_f32_into`]: one real input plane (batch × n) in,
    /// two spectrum planes (batch × (n/2+1)) out, caller-owned buffers
    /// resized (never shrunk) and fully overwritten.
    pub fn run_rfft_f32_into(
        &self,
        x: &[f32],
        out_re: &mut Vec<f32>,
        out_im: &mut Vec<f32>,
    ) -> Result<()> {
        anyhow::ensure!(
            self.meta.kind == "rfft",
            "run_rfft_f32_into on '{}' (kind {})",
            self.meta.name,
            self.meta.kind
        );
        self.check_inputs(1, [x.len()].into_iter())?;
        self.exec_rfft_into(x, out_re, out_im);
        Ok(())
    }

    /// Zero-copy serving path for `conv` artifacts, mirroring
    /// [`Self::run_fft_f32_into`]: one real input plane (batch × n) in,
    /// one filtered plane (batch × n) out, caller-owned buffer resized
    /// (never shrunk) and fully overwritten. Filtering runs natively in
    /// f32 against the pre-narrowed kernel spectrum.
    pub fn run_conv_f32_into(&self, x: &[f32], out: &mut Vec<f32>) -> Result<()> {
        anyhow::ensure!(
            self.meta.kind == "conv",
            "run_conv_f32_into on '{}' (kind {})",
            self.meta.name,
            self.meta.kind
        );
        self.check_inputs(1, [x.len()].into_iter())?;
        self.exec_conv_into(x, out);
        Ok(())
    }

    /// The one conv execution body (callers have validated inputs).
    fn exec_conv_into(&self, x: &[f32], y: &mut Vec<f32>) {
        let n = self.meta.n as usize;
        let batch = self.meta.batch as usize;
        // No zero-fill: run_conv_rows overwrites every element of `y`.
        resize_for_overwrite(y, batch * n);
        let plan = self.cplan();
        planner::run_conv_rows(&plan, x, batch, y);
    }

    /// The one rfft execution body (callers have validated inputs).
    fn exec_rfft_into(&self, x: &[f32], out_re: &mut Vec<f32>, out_im: &mut Vec<f32>) {
        let batch = self.meta.batch as usize;
        let rplan = self.rplan();
        let o = rplan.out_len();
        // No zero-fill: run_rfft_rows overwrites every element out to
        // batch × (n/2+1) of both spectrum planes.
        resize_for_overwrite(out_re, batch * o);
        resize_for_overwrite(out_im, batch * o);
        planner::run_rfft_rows(&rplan, x, batch, out_re, out_im);
    }

    /// Build "input literals". The sim backend has no device buffers; this
    /// exists so benches exercising setup-vs-run splits still compile.
    pub fn literals_f32(&self, inputs: &[&[f32]]) -> Result<Vec<Vec<f32>>> {
        self.check_inputs(inputs.len(), inputs.iter().map(|i| i.len()))?;
        Ok(inputs.iter().map(|i| i.to_vec()).collect())
    }

    /// Execute pre-built literals.
    pub fn run_literals(&self, literals: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        let planes: Vec<&[f32]> = literals.iter().map(|l| l.as_slice()).collect();
        self.run_f32(&planes)
    }

    /// Execute with f64 planes (the fp64 artifacts).
    pub fn run_f64(&self, inputs: &[&[f64]]) -> Result<Vec<Vec<f64>>> {
        self.check_inputs(inputs.len(), inputs.iter().map(|i| i.len()))?;
        anyhow::ensure!(
            self.meta.kind == "fft",
            "sim backend only runs fft artifacts in f64"
        );
        let (re, im) = (inputs[0], inputs[1]);
        let n = self.meta.n as usize;
        let batch = self.meta.batch as usize;
        let plan = self.plan();
        let mut out_re = vec![0.0f64; batch * n];
        let mut out_im = vec![0.0f64; batch * n];
        planner::run_rows(&plan, Direction::Forward, re, im, batch, &mut out_re, &mut out_im);
        Ok(vec![out_re, out_im])
    }

    fn check_inputs(&self, got: usize, lens: impl Iterator<Item = usize>) -> Result<()> {
        let shapes = self.meta.input_shapes();
        anyhow::ensure!(
            got == shapes.len(),
            "artifact {} wants {} inputs, got {got}",
            self.meta.name,
            shapes.len()
        );
        for (len, (_ty, dims)) in lens.zip(&shapes) {
            let want: u64 = dims.iter().product();
            anyhow::ensure!(
                want == len as u64,
                "artifact {} input wants {want} elements, got {len}",
                self.meta.name
            );
        }
        Ok(())
    }
}

/// The simulated runtime: manifest (on-disk or synthetic) + a load cache.
///
/// The cache is a `RwLock` so the hot path (cache hit) takes only a read
/// lock; concurrent misses both validate outside the lock and the
/// write-side entry API keeps whichever module landed first, so racing
/// loaders converge on one shared `Arc` (no double-load divergence).
pub struct Runtime {
    manifest: Manifest,
    cache: RwLock<HashMap<String, Arc<LoadedModule>>>,
}

impl Runtime {
    /// Create against an artifact directory. Reads `manifest.tsv` when
    /// present; otherwise synthesizes the standard artifact set so the
    /// serving stack works in a fresh checkout.
    pub fn new(artifact_dir: &Path) -> Result<Self> {
        let manifest = if artifact_dir.join("manifest.tsv").exists() {
            Manifest::load(artifact_dir)?
        } else {
            Manifest::synthetic(artifact_dir)
        };
        Ok(Self {
            manifest,
            cache: RwLock::new(HashMap::new()),
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform(&self) -> String {
        "sim-cpu (dsp oracle; build with --features xla for PJRT)".to_string()
    }

    /// Load an artifact (cached). Real on-disk artifacts are digest- and
    /// header-checked; synthetic entries load directly.
    /// Poison-recovering cache locks: a panic in one loader thread must
    /// not wedge every other card's module loads — the map's contents are
    /// valid `Arc`s under any interleaving (worst case a module re-loads).
    fn cache_read(&self) -> std::sync::RwLockReadGuard<'_, HashMap<String, Arc<LoadedModule>>> {
        self.cache.read().unwrap_or_else(std::sync::PoisonError::into_inner)
    }

    fn cache_write(&self) -> std::sync::RwLockWriteGuard<'_, HashMap<String, Arc<LoadedModule>>> {
        self.cache.write().unwrap_or_else(std::sync::PoisonError::into_inner)
    }

    pub fn load(&self, name: &str) -> Result<Arc<LoadedModule>> {
        if let Some(m) = self.cache_read().get(name) {
            return Ok(m.clone());
        }
        let meta = self.manifest.get(name)?.clone();
        anyhow::ensure!(
            planner::supports(meta.n as usize),
            "artifact {name}: transform length {} has no plan support",
            meta.n
        );
        check_artifact_on_load(&meta)?;
        let module = Arc::new(LoadedModule::new(meta));
        // First inserter wins: a load racing this one returns the already
        // cached module instead of installing a second copy.
        Ok(self
            .cache_write()
            .entry(name.to_string())
            .or_insert(module)
            .clone())
    }

    /// Names of all artifacts currently loaded, sorted (stable for logs
    /// and assertions regardless of hash order).
    pub fn loaded_names(&self) -> Vec<String> {
        let mut names: Vec<String> = self.cache_read().keys().cloned().collect();
        names.sort();
        names
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::validation::sha256_16;
    use crate::util::rng::Rng;

    fn rt() -> Runtime {
        Runtime::new(Path::new("/nonexistent-artifacts")).unwrap()
    }

    #[test]
    fn synthetic_runtime_serves_fft() {
        let rt = rt();
        let m = rt.load("fft_f32_n256_b256").unwrap();
        let total = (m.meta.batch * m.meta.n) as usize;
        let mut rng = Rng::new(1);
        let re: Vec<f32> = (0..total).map(|_| rng.gauss() as f32).collect();
        let im: Vec<f32> = (0..total).map(|_| rng.gauss() as f32).collect();
        let out = m.run_f32(&[&re, &im]).unwrap();
        assert_eq!(out.len(), 2);
        assert_eq!(out[0].len(), total);
        // sanity: Parseval (tolerance sized for native-f32 execution —
        // the planner computes f32 jobs in f32 planes end-to-end now)
        let n = m.meta.n as usize;
        let e_time: f64 = (0..n)
            .map(|i| (re[i] as f64).powi(2) + (im[i] as f64).powi(2))
            .sum();
        let e_freq: f64 = (0..n)
            .map(|i| (out[0][i] as f64).powi(2) + (out[1][i] as f64).powi(2))
            .sum::<f64>()
            / n as f64;
        assert!((e_time - e_freq).abs() < 1e-4 * e_time.max(1.0));
    }

    #[test]
    fn wrong_input_arity_or_shape_rejected() {
        let rt = rt();
        let m = rt.load("fft_f32_n256_b256").unwrap();
        let total = (m.meta.batch * m.meta.n) as usize;
        let plane = vec![0.0f32; total];
        assert!(m.run_f32(&[&plane]).is_err(), "arity");
        let short = vec![0.0f32; total - 1];
        assert!(m.run_f32(&[&short, &plane]).is_err(), "shape");
    }

    #[test]
    fn unknown_artifact_rejected() {
        let rt = rt();
        assert!(rt.load("fft_f32_n512_b1").is_err());
    }

    #[test]
    fn load_is_cached() {
        let rt = rt();
        let a = rt.load("fft_f32_n1024_b64").unwrap();
        let b = rt.load("fft_f32_n1024_b64").unwrap();
        assert!(Arc::ptr_eq(&a, &b), "cache hit must return the same module");
        assert_eq!(rt.loaded_names(), vec!["fft_f32_n1024_b64".to_string()]);
    }

    #[test]
    fn loaded_names_are_sorted() {
        let rt = rt();
        // Load in non-sorted order; the listing must come back sorted.
        rt.load("fft_f32_n256_b256").unwrap();
        rt.load("fft_f32_n1024_b64").unwrap();
        rt.load("fft_f32_n16384_b4").unwrap();
        let names = rt.loaded_names();
        let mut sorted = names.clone();
        sorted.sort();
        assert_eq!(names, sorted);
        assert_eq!(names.len(), 3);
    }

    #[test]
    fn concurrent_loads_converge_on_one_module() {
        let rt = Arc::new(rt());
        let handles: Vec<_> = (0..8)
            .map(|_| {
                let rt = rt.clone();
                std::thread::spawn(move || rt.load("fft_f32_n4096_b16").unwrap())
            })
            .collect();
        let modules: Vec<_> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        // First insert wins; every racer gets a clone of the cached Arc.
        let canonical = rt.load("fft_f32_n4096_b16").unwrap();
        assert!(modules.iter().all(|m| Arc::ptr_eq(m, &canonical)));
        assert_eq!(rt.loaded_names(), vec!["fft_f32_n4096_b16".to_string()]);
    }

    #[test]
    fn run_into_matches_run_and_reuses_buffers() {
        let rt = rt();
        let m = rt.load("fft_f32_n256_b256").unwrap();
        let total = (m.meta.batch * m.meta.n) as usize;
        let mut rng = Rng::new(8);
        let re: Vec<f32> = (0..total).map(|_| rng.gauss() as f32).collect();
        let im: Vec<f32> = (0..total).map(|_| rng.gauss() as f32).collect();
        let want = m.run_f32(&[&re, &im]).unwrap();
        let mut out_re = Vec::new();
        let mut out_im = Vec::new();
        m.run_fft_f32_into(&re, &im, &mut out_re, &mut out_im).unwrap();
        assert_eq!(out_re, want[0]);
        assert_eq!(out_im, want[1]);
        // Second run reuses the same output allocations.
        let ptr = out_re.as_ptr();
        m.run_fft_f32_into(&re, &im, &mut out_re, &mut out_im).unwrap();
        assert_eq!(out_re.as_ptr(), ptr, "steady state must not reallocate outputs");
    }

    #[test]
    fn run_into_rejects_non_fft_kinds() {
        let rt = rt();
        let m = rt.load("spectrum_f32_n4096_b16").unwrap();
        let total = (m.meta.batch * m.meta.n) as usize;
        let plane = vec![0.0f32; total];
        let (mut a, mut b) = (Vec::new(), Vec::new());
        assert!(m.run_fft_f32_into(&plane, &plane, &mut a, &mut b).is_err());
        assert!(m.run_rfft_f32_into(&plane, &mut a, &mut b).is_err());
    }

    #[test]
    fn synthetic_runtime_serves_non_pow2_ffts() {
        // The off-grid serving lengths the issue opens: mixed-radix 1000
        // (2³·5³) and 1536 (2⁹·3) through the standard fft path.
        let rt = rt();
        for name in ["fft_f32_n1000_b64", "fft_f32_n1536_b64"] {
            let m = rt.load(name).unwrap();
            let n = m.meta.n as usize;
            let total = m.meta.batch as usize * n;
            let mut rng = Rng::new(21);
            let re: Vec<f32> = (0..total).map(|_| rng.gauss() as f32).collect();
            let im: Vec<f32> = (0..total).map(|_| rng.gauss() as f32).collect();
            let out = m.run_f32(&[&re, &im]).unwrap();
            // row 0 against the naive DFT (the only oracle for non-pow2)
            let x: Vec<crate::dsp::C64> = (0..n)
                .map(|i| crate::dsp::C64::new(re[i] as f64, im[i] as f64))
                .collect();
            let want = crate::dsp::fft::dft_naive(&x);
            for i in 0..n {
                assert!(
                    (out[0][i] as f64 - want[i].re).abs() < 1e-2
                        && (out[1][i] as f64 - want[i].im).abs() < 1e-2,
                    "{name} bin {i}"
                );
            }
        }
    }

    #[test]
    fn synthetic_runtime_serves_rfft() {
        let rt = rt();
        let m = rt.load("rfft_f32_n4096_b16").unwrap();
        let n = m.meta.n as usize;
        let o = n / 2 + 1;
        let batch = m.meta.batch as usize;
        let mut rng = Rng::new(9);
        let x: Vec<f32> = (0..batch * n).map(|_| rng.gauss() as f32).collect();
        let out = m.run_f32(&[&x]).unwrap();
        assert_eq!(out.len(), 2);
        assert_eq!(out[0].len(), batch * o);
        // row 0 against the complex oracle on the same real signal
        let xc: Vec<crate::dsp::C64> = (0..n)
            .map(|i| crate::dsp::C64::new(x[i] as f64, 0.0))
            .collect();
        let want = crate::dsp::fft(&xc);
        for k in 0..o {
            assert!(
                (out[0][k] as f64 - want[k].re).abs() < 1e-2
                    && (out[1][k] as f64 - want[k].im).abs() < 1e-2,
                "bin {k}"
            );
        }
        // the zero-copy path matches and reuses buffers
        let (mut a, mut b) = (Vec::new(), Vec::new());
        m.run_rfft_f32_into(&x, &mut a, &mut b).unwrap();
        assert_eq!(a, out[0]);
        assert_eq!(b, out[1]);
        let ptr = a.as_ptr();
        m.run_rfft_f32_into(&x, &mut a, &mut b).unwrap();
        assert_eq!(a.as_ptr(), ptr, "steady state must not reallocate");
        // wrong arity/shape rejected
        assert!(m.run_f32(&[&x, &x]).is_err(), "rfft takes one plane");
        let short = vec![0.0f32; batch * n - 1];
        assert!(m.run_rfft_f32_into(&short, &mut a, &mut b).is_err());
    }

    #[test]
    fn synthetic_runtime_serves_conv() {
        let rt = rt();
        let m = rt.load("conv_f32_n4096_t129_b16").unwrap();
        assert_eq!(m.meta.kind, "conv");
        let n = m.meta.n as usize;
        let taps = m.meta.harmonics as usize;
        let batch = m.meta.batch as usize;
        let mut rng = Rng::new(33);
        let x: Vec<f32> = (0..batch * n).map(|_| rng.gauss() as f32).collect();
        let out = m.run_f32(&[&x]).unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].len(), batch * n);
        // row 0 against the direct causal FIR with the same kernel
        let h = crate::dsp::planner::synthetic_kernel(taps);
        for t in (0..n).step_by(37) {
            let mut want = 0.0f64;
            for (j, &hj) in h.iter().enumerate() {
                if t >= j {
                    want += hj * x[t - j] as f64;
                }
            }
            assert!(
                (out[0][t] as f64 - want).abs() < 1e-4,
                "t={t}: {} vs {want}",
                out[0][t]
            );
        }
        // the zero-copy path matches and reuses buffers
        let mut y = Vec::new();
        m.run_conv_f32_into(&x, &mut y).unwrap();
        assert_eq!(y, out[0]);
        let ptr = y.as_ptr();
        m.run_conv_f32_into(&x, &mut y).unwrap();
        assert_eq!(y.as_ptr(), ptr, "steady state must not reallocate");
        // wrong kind / arity / shape rejected
        let fft = rt.load("fft_f32_n1024_b64").unwrap();
        assert!(fft.run_conv_f32_into(&x, &mut y).is_err(), "kind");
        assert!(m.run_f32(&[&x, &x]).is_err(), "conv takes one plane");
        let short = vec![0.0f32; batch * n - 1];
        assert!(m.run_conv_f32_into(&short, &mut y).is_err(), "shape");
    }

    #[test]
    fn synthetic_runtime_serves_large_n_four_step() {
        // The 2^18 serving entry must route through the four-step plan and
        // still satisfy Parseval (the cheap large-N correctness check).
        let rt = rt();
        let m = rt.load("fft_f32_n262144_b2").unwrap();
        let n = m.meta.n as usize;
        assert!(
            crate::dsp::planner::plan_for(n).is_four_step(),
            "2^18 must compile to the four-step path"
        );
        let total = m.meta.batch as usize * n;
        let mut rng = Rng::new(64);
        let re: Vec<f32> = (0..total).map(|_| rng.gauss() as f32).collect();
        let im: Vec<f32> = (0..total).map(|_| rng.gauss() as f32).collect();
        let out = m.run_f32(&[&re, &im]).unwrap();
        let e_time: f64 = (0..n)
            .map(|i| (re[i] as f64).powi(2) + (im[i] as f64).powi(2))
            .sum();
        let e_freq: f64 = (0..n)
            .map(|i| (out[0][i] as f64).powi(2) + (out[1][i] as f64).powi(2))
            .sum::<f64>()
            / n as f64;
        assert!((e_time - e_freq).abs() < 1e-3 * e_time.max(1.0));
    }

    #[test]
    fn on_disk_artifacts_are_digest_checked() {
        let dir = std::env::temp_dir().join(format!("fftsweep_sim_rt_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let good = "HloModule sim_check\nENTRY main {}\n";
        std::fs::write(dir.join("good.hlo.txt"), good).unwrap();
        let digest = sha256_16(good.as_bytes());
        let manifest = format!(
            "name\tfile\tkind\tn\tbatch\tdtype\tharmonics\tinputs\tn_outputs\tsha256_16\n\
             good\tgood.hlo.txt\tfft\t8\t1\tf32\t0\tf32:1x8;f32:1x8\t2\t{digest}\n\
             tampered\tgood.hlo.txt\tfft\t8\t1\tf32\t0\tf32:1x8;f32:1x8\t2\t0000000000000000\n"
        );
        std::fs::write(dir.join("manifest.tsv"), manifest).unwrap();
        let rt = Runtime::new(&dir).unwrap();
        assert!(rt.load("good").is_ok());
        assert!(rt.load("tampered").is_err(), "digest mismatch must fail loud");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
