//! L3 runtime: load AOT artifacts (HLO text) and execute them from rust.
//! Python never runs here.
//!
//! Two interchangeable backends behind one API:
//!   * `client` (feature `xla`): compile-once PJRT CPU execution of the
//!     real HLO text — requires the native `xla_extension` binding (see
//!     Cargo.toml header note),
//!   * `sim_client` (default): a pure-rust backend that executes artifacts
//!     with the DSP oracle and synthesizes a manifest when none is on
//!     disk, so the serving stack runs in hermetic environments.

pub mod artifact;
#[cfg(feature = "xla")]
pub mod client;
#[cfg(not(feature = "xla"))]
pub mod sim_client;
pub mod validation;

pub use artifact::{ArtifactMeta, Manifest};
#[cfg(feature = "xla")]
pub use client::{LoadedModule, Runtime};
#[cfg(not(feature = "xla"))]
pub use sim_client::{LoadedModule, Runtime};
