//! L3 runtime: load AOT artifacts (HLO text) and execute them from rust.
//! Python never runs here.
//!
//! Interchangeable backends behind one capability-discovering trait
//! ([`backend::ExecBackend`] — the surface the coordinator, governors,
//! CLI and benches program against):
//!   * `client` (feature `xla`): compile-once PJRT CPU execution of the
//!     real HLO text — requires the native `xla_extension` binding (see
//!     Cargo.toml header note),
//!   * `sim_client` (default): a pure-rust backend that executes artifacts
//!     with the DSP oracle and synthesizes a manifest when none is on
//!     disk, so the serving stack runs in hermetic environments,
//!   * `backend::CufftProfileBackend` (all feature sets): replays the
//!     paper-calibrated cuFFT plan model for timing while executing
//!     numerics through the planned DSP engine.

pub mod artifact;
pub mod backend;
#[cfg(feature = "xla")]
pub mod client;
#[cfg(not(feature = "xla"))]
pub mod sim_client;
pub mod validation;

pub use artifact::{ArtifactMeta, Manifest};
pub use backend::{
    backend_by_name, compiled_backend_names, default_backend, BackendCaps, BackendError,
    CufftProfileBackend, ExecBackend, ExecModule, IntoBackend,
};
#[cfg(feature = "xla")]
pub use backend::XlaBackend;
#[cfg(not(feature = "xla"))]
pub use backend::SimBackend;
#[cfg(feature = "xla")]
pub use client::{LoadedModule, Runtime};
#[cfg(not(feature = "xla"))]
pub use sim_client::{LoadedModule, Runtime};
