//! L3 runtime: load AOT artifacts (HLO text), compile once on the PJRT CPU
//! client, execute from rust. Python never runs here.

pub mod artifact;
pub mod client;
pub mod validation;

pub use artifact::{ArtifactMeta, Manifest};
pub use client::{LoadedModule, Runtime};
