//! Artifact validation: verify that the on-disk HLO text matches the
//! manifest digests and contains no elided constants (the silent-zeros
//! failure mode the AOT guard also checks — defense in depth on the
//! consumer side).

use std::path::Path;

use anyhow::{bail, Context, Result};

use super::artifact::{ArtifactMeta, Manifest};

/// A validation finding for one artifact.
#[derive(Debug, Clone)]
pub struct Finding {
    pub artifact: String,
    pub issue: Issue,
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Issue {
    MissingFile,
    DigestMismatch { expected: String, actual: String },
    ElidedConstants,
    NotHloText,
}

/// sha256 (pure-rust, compact) — first 16 hex chars, matching aot.py.
pub fn sha256_16(data: &[u8]) -> String {
    let digest = sha256(data);
    digest.iter().take(8).map(|b| format!("{b:02x}")).collect()
}

fn sha256(data: &[u8]) -> [u8; 32] {
    const K: [u32; 64] = [
        0x428a2f98, 0x71374491, 0xb5c0fbcf, 0xe9b5dba5, 0x3956c25b, 0x59f111f1, 0x923f82a4,
        0xab1c5ed5, 0xd807aa98, 0x12835b01, 0x243185be, 0x550c7dc3, 0x72be5d74, 0x80deb1fe,
        0x9bdc06a7, 0xc19bf174, 0xe49b69c1, 0xefbe4786, 0x0fc19dc6, 0x240ca1cc, 0x2de92c6f,
        0x4a7484aa, 0x5cb0a9dc, 0x76f988da, 0x983e5152, 0xa831c66d, 0xb00327c8, 0xbf597fc7,
        0xc6e00bf3, 0xd5a79147, 0x06ca6351, 0x14292967, 0x27b70a85, 0x2e1b2138, 0x4d2c6dfc,
        0x53380d13, 0x650a7354, 0x766a0abb, 0x81c2c92e, 0x92722c85, 0xa2bfe8a1, 0xa81a664b,
        0xc24b8b70, 0xc76c51a3, 0xd192e819, 0xd6990624, 0xf40e3585, 0x106aa070, 0x19a4c116,
        0x1e376c08, 0x2748774c, 0x34b0bcb5, 0x391c0cb3, 0x4ed8aa4a, 0x5b9cca4f, 0x682e6ff3,
        0x748f82ee, 0x78a5636f, 0x84c87814, 0x8cc70208, 0x90befffa, 0xa4506ceb, 0xbef9a3f7,
        0xc67178f2,
    ];
    let mut h: [u32; 8] = [
        0x6a09e667, 0xbb67ae85, 0x3c6ef372, 0xa54ff53a, 0x510e527f, 0x9b05688c, 0x1f83d9ab,
        0x5be0cd19,
    ];
    let mut msg = data.to_vec();
    let bitlen = (data.len() as u64) * 8;
    msg.push(0x80);
    while msg.len() % 64 != 56 {
        msg.push(0);
    }
    msg.extend_from_slice(&bitlen.to_be_bytes());
    for chunk in msg.chunks(64) {
        let mut w = [0u32; 64];
        for i in 0..16 {
            w[i] = u32::from_be_bytes(chunk[4 * i..4 * i + 4].try_into().unwrap());
        }
        for i in 16..64 {
            let s0 = w[i - 15].rotate_right(7) ^ w[i - 15].rotate_right(18) ^ (w[i - 15] >> 3);
            let s1 = w[i - 2].rotate_right(17) ^ w[i - 2].rotate_right(19) ^ (w[i - 2] >> 10);
            w[i] = w[i - 16]
                .wrapping_add(s0)
                .wrapping_add(w[i - 7])
                .wrapping_add(s1);
        }
        let (mut a, mut b, mut c, mut d, mut e, mut f, mut g, mut hh) =
            (h[0], h[1], h[2], h[3], h[4], h[5], h[6], h[7]);
        for i in 0..64 {
            let s1 = e.rotate_right(6) ^ e.rotate_right(11) ^ e.rotate_right(25);
            let ch = (e & f) ^ ((!e) & g);
            let t1 = hh
                .wrapping_add(s1)
                .wrapping_add(ch)
                .wrapping_add(K[i])
                .wrapping_add(w[i]);
            let s0 = a.rotate_right(2) ^ a.rotate_right(13) ^ a.rotate_right(22);
            let maj = (a & b) ^ (a & c) ^ (b & c);
            let t2 = s0.wrapping_add(maj);
            hh = g;
            g = f;
            f = e;
            e = d.wrapping_add(t1);
            d = c;
            c = b;
            b = a;
            a = t1.wrapping_add(t2);
        }
        h[0] = h[0].wrapping_add(a);
        h[1] = h[1].wrapping_add(b);
        h[2] = h[2].wrapping_add(c);
        h[3] = h[3].wrapping_add(d);
        h[4] = h[4].wrapping_add(e);
        h[5] = h[5].wrapping_add(f);
        h[6] = h[6].wrapping_add(g);
        h[7] = h[7].wrapping_add(hh);
    }
    let mut out = [0u8; 32];
    for (i, v) in h.iter().enumerate() {
        out[4 * i..4 * i + 4].copy_from_slice(&v.to_be_bytes());
    }
    out
}

/// Load-time artifact gate — the one digest/header check both runtime
/// backends call before trusting an on-disk artifact (the two used to
/// carry private copies that drifted on error wording; the PJRT side
/// then lost the check entirely). Synthetic manifest entries (digest
/// `Manifest::SIMULATED_DIGEST`) have nothing on disk to verify and pass
/// through; real entries must be HLO text whose digest matches the
/// manifest.
pub fn check_artifact_on_load(meta: &ArtifactMeta) -> Result<()> {
    if meta.digest == Manifest::SIMULATED_DIGEST {
        return Ok(());
    }
    let text = std::fs::read_to_string(&meta.file)
        .with_context(|| format!("reading HLO text {:?}", meta.file))?;
    if !text.starts_with("HloModule") {
        bail!("artifact {}: {:?} is not HLO text", meta.name, meta.file);
    }
    let actual = sha256_16(text.as_bytes());
    if actual != meta.digest {
        bail!(
            "artifact {}: digest mismatch ({actual} vs manifest {})",
            meta.name,
            meta.digest
        );
    }
    Ok(())
}

/// Validate every artifact in a manifest. Empty vec == all good.
pub fn validate(manifest: &Manifest) -> Vec<Finding> {
    let mut findings = Vec::new();
    for a in manifest.entries.values() {
        let Ok(text) = std::fs::read_to_string(&a.file) else {
            findings.push(Finding {
                artifact: a.name.clone(),
                issue: Issue::MissingFile,
            });
            continue;
        };
        if !text.starts_with("HloModule") {
            findings.push(Finding {
                artifact: a.name.clone(),
                issue: Issue::NotHloText,
            });
            continue;
        }
        if text.contains("constant({...})") {
            findings.push(Finding {
                artifact: a.name.clone(),
                issue: Issue::ElidedConstants,
            });
        }
        let actual = sha256_16(text.as_bytes());
        if actual != a.digest {
            findings.push(Finding {
                artifact: a.name.clone(),
                issue: Issue::DigestMismatch {
                    expected: a.digest.clone(),
                    actual,
                },
            });
        }
    }
    findings
}

/// Validate a directory, erroring on any finding.
pub fn validate_dir(dir: &Path) -> Result<usize> {
    let manifest = Manifest::load(dir)?;
    let findings = validate(&manifest);
    if !findings.is_empty() {
        bail!(
            "artifact validation failed:\n{}",
            findings
                .iter()
                .map(|f| format!("  {}: {:?}", f.artifact, f.issue))
                .collect::<Vec<_>>()
                .join("\n")
        );
    }
    Ok(manifest.entries.len())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sha256_known_vectors() {
        // sha256("") = e3b0c44298fc1c14...
        assert_eq!(sha256_16(b""), "e3b0c44298fc1c14");
        // sha256("abc") = ba7816bf8f01cfea...
        assert_eq!(sha256_16(b"abc"), "ba7816bf8f01cfea");
        // longer-than-one-block input
        let long = vec![b'a'; 1000];
        assert_eq!(sha256(&long).len(), 32);
    }

    #[test]
    fn validate_detects_problems() {
        let dir = std::env::temp_dir().join(format!("fftsweep_val_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let good = "HloModule test\nENTRY main {}\n";
        std::fs::write(dir.join("good.hlo.txt"), good).unwrap();
        std::fs::write(dir.join("elided.hlo.txt"), "HloModule t\nconstant({...})\n").unwrap();
        std::fs::write(dir.join("binary.hlo.txt"), "\x08\x01 proto bytes").unwrap();
        let digest = sha256_16(good.as_bytes());
        let manifest_text = format!(
            "name\tfile\tkind\tn\tbatch\tdtype\tharmonics\tinputs\tn_outputs\tsha256_16\n\
             good\tgood.hlo.txt\tfft\t8\t1\tf32\t0\tf32:1x8;f32:1x8\t2\t{digest}\n\
             bad_digest\tgood.hlo.txt\tfft\t8\t1\tf32\t0\tf32:1x8;f32:1x8\t2\t0000000000000000\n\
             elided\telided.hlo.txt\tfft\t8\t1\tf32\t0\tf32:1x8;f32:1x8\t2\tffffffffffffffff\n\
             binary\tbinary.hlo.txt\tfft\t8\t1\tf32\t0\tf32:1x8;f32:1x8\t2\tffffffffffffffff\n\
             missing\tnope.hlo.txt\tfft\t8\t1\tf32\t0\tf32:1x8;f32:1x8\t2\tffffffffffffffff\n"
        );
        std::fs::write(dir.join("manifest.tsv"), manifest_text).unwrap();
        let manifest = Manifest::load(&dir).unwrap();
        let findings = validate(&manifest);
        let by_name = |n: &str| findings.iter().find(|f| f.artifact == n);
        assert!(by_name("good").is_none());
        assert!(matches!(by_name("bad_digest").unwrap().issue, Issue::DigestMismatch { .. }));
        assert_eq!(by_name("elided").unwrap().issue, Issue::ElidedConstants);
        assert_eq!(by_name("binary").unwrap().issue, Issue::NotHloText);
        assert_eq!(by_name("missing").unwrap().issue, Issue::MissingFile);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn real_artifacts_validate_if_present() {
        let dir = Manifest::default_dir();
        if dir.join("manifest.tsv").exists() {
            let n = validate_dir(&dir).expect("artifacts must validate");
            assert!(n >= 5);
        }
    }
}
