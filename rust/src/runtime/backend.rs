//! The unified execution-backend surface: one capability-discovering
//! `ExecBackend` trait in front of every runtime the serving stack can
//! target — the hermetic DSP-oracle sim (`sim_client`), the real PJRT
//! client (`client`, behind the `xla` feature) and a cuFFT plan-model
//! replay backend (`cufft/`), so the coordinator, governors, CLI and
//! benches program against `dyn ExecBackend` instead of a per-module
//! `Runtime` type kept in sync by hand.
//!
//! The contract, pinned by `tests/backend_contract.rs`:
//!   * `capabilities()` is honest — every artifact the backend's manifest
//!     advertises within the capability envelope loads and runs; every
//!     request outside it fails with the typed [`BackendError`],
//!   * the batch entry points (`run_fft_into` / `run_rfft_into` /
//!     `run_conv_into`) share one signature shape: input planes as
//!     slices, output planes as caller-owned `Vec`s that are resized
//!     (never shrunk below need) and fully overwritten,
//!   * `estimate_time_s` is monotone in N across kernel-count boundaries
//!     (the paper's execution-time staircase, Figs 4/5).

use std::any::Any;
use std::path::Path;
use std::sync::Arc;

use anyhow::Result;

use super::artifact::{ArtifactMeta, Manifest};
use crate::sim::gpu::GpuSpec;
use crate::types::{FftWorkload, Precision};

/// Typed refusal: the single error shape every backend returns for a
/// request outside its capability envelope, so admission control and the
/// contract suite can match on it instead of parsing message strings.
#[derive(Debug, thiserror::Error, PartialEq, Eq)]
pub enum BackendError {
    #[error("backend '{backend}': kind '{kind}' n={n} outside capability envelope")]
    Unsupported {
        backend: &'static str,
        kind: String,
        n: u64,
    },
}

/// What a backend can execute, discovered once and consulted at admission
/// time (the `Batcher` refuses out-of-envelope jobs with a typed
/// `CoordError` instead of letting a worker thread panic).
#[derive(Debug, Clone)]
pub struct BackendCaps {
    /// Backend name (matches [`ExecBackend::name`]).
    pub backend: &'static str,
    /// Executable artifact kinds ("fft", "rfft", "conv", "spectrum", ...).
    pub kinds: Vec<&'static str>,
    /// Transform-length envelope (inclusive).
    pub min_n: u64,
    pub max_n: u64,
    /// True if only power-of-two lengths run (the FP16-style restriction).
    pub pow2_only: bool,
    /// Precisions with native execution support.
    pub precisions: Vec<Precision>,
    /// True when inputs/outputs are split re/im planes (all current
    /// backends; a future interleaved-layout backend would clear it).
    pub split_complex_planes: bool,
    /// Whether the execution target honors locked-clock requests (DVFS).
    pub locked_clocks: bool,
    /// Whether NVML-style power telemetry is read from real hardware
    /// (false everywhere today: the sim synthesizes draw, PJRT-CPU and
    /// the cufft replay have no sensor).
    pub nvml: bool,
    /// Device memory of the modeled/attached card, bytes (0 = host).
    pub device_mem_bytes: u64,
    /// L2/residency budget the planner blocks against, bytes.
    pub l2_bytes: u64,
    /// Roofline inputs: device- and shared-memory bandwidth of the
    /// modeled card, GB/s (what `analysis::roofline::classify_plan`
    /// prices plans against).
    pub dev_bw_gbs: f64,
    pub shared_bw_gbs: f64,
}

impl BackendCaps {
    /// Length-only admission check (what the `Batcher` gates `push` on).
    pub fn supports_len(&self, n: u64) -> bool {
        n >= self.min_n && n <= self.max_n && (!self.pow2_only || n.is_power_of_two())
    }

    /// Full (kind, n, precision) capability check.
    pub fn supports(&self, kind: &str, n: u64, precision: Precision) -> bool {
        self.kinds.iter().any(|k| *k == kind)
            && self.supports_len(n)
            && self.precisions.contains(&precision)
    }

    /// One-line header for CLI tables, so replay output is attributable
    /// to a backend (`fftsweep telemetry` / `govern` print this).
    pub fn summary(&self) -> String {
        let precisions: Vec<&str> = self.precisions.iter().map(|p| p.label()).collect();
        format!(
            "backend {}: kinds [{}], n {}..={}{}, precisions [{}], locked-clocks {}, nvml {}, l2 {} KiB",
            self.backend,
            self.kinds.join(","),
            self.min_n,
            if self.max_n == u64::MAX { "inf".to_string() } else { self.max_n.to_string() },
            if self.pow2_only { " (pow2 only)" } else { "" },
            precisions.join(","),
            self.locked_clocks,
            self.nvml,
            self.l2_bytes / 1024,
        )
    }
}

/// A loaded artifact as the coordinator sees it: metadata plus an opaque
/// backend-private payload (the sim's resolved plans, PJRT's compiled
/// executable, the cufft replay's plan descriptor). Workers cache these
/// per `(artifact)` and hand them back to the owning backend to execute.
pub struct ExecModule {
    pub meta: ArtifactMeta,
    raw: Arc<dyn Any + Send + Sync>,
}

impl ExecModule {
    pub fn new(meta: ArtifactMeta, raw: Arc<dyn Any + Send + Sync>) -> Self {
        Self { meta, raw }
    }

    /// Recover the backend-private payload. Fails (rather than panics) on
    /// a cross-backend mix-up — a module loaded by one backend handed to
    /// another for execution.
    fn downcast<T: Send + Sync + 'static>(&self) -> Result<Arc<T>> {
        self.raw.clone().downcast::<T>().map_err(|_| {
            anyhow::anyhow!(
                "module '{}' was not loaded by this backend (payload type mismatch)",
                self.meta.name
            )
        })
    }
}

/// The one runtime surface the serving stack programs against.
pub trait ExecBackend: Send + Sync {
    /// Stable short name ("sim", "xla", "cufft-profile").
    fn name(&self) -> &'static str;

    /// Discover what this backend can execute.
    fn capabilities(&self) -> BackendCaps;

    /// The artifact manifest this backend serves (routing tables and
    /// prewarm derive from it).
    fn manifest(&self) -> &Manifest;

    /// Human-readable execution-platform description.
    fn platform(&self) -> String;

    /// Load (and on compiled backends, compile) an artifact by manifest
    /// name. Cached; concurrent loads converge on one module.
    fn load(&self, name: &str) -> Result<Arc<ExecModule>>;

    /// Names of all artifacts currently loaded, sorted.
    fn loaded_names(&self) -> Vec<String>;

    /// Batched C2C transform: two (batch, n) input planes in, two out.
    /// Output vecs are sized by the callee and fully overwritten.
    fn run_fft_into(
        &self,
        module: &ExecModule,
        re: &[f32],
        im: &[f32],
        out_re: &mut Vec<f32>,
        out_im: &mut Vec<f32>,
    ) -> Result<()>;

    /// Batched real-input transform: one (batch, n) real plane in, two
    /// (batch, n/2+1) spectrum planes out.
    fn run_rfft_into(
        &self,
        module: &ExecModule,
        x: &[f32],
        out_re: &mut Vec<f32>,
        out_im: &mut Vec<f32>,
    ) -> Result<()>;

    /// Batched FFT-domain FIR filtering: one (batch, n) real plane in,
    /// one filtered (batch, n) plane out.
    fn run_conv_into(&self, module: &ExecModule, x: &[f32], out: &mut Vec<f32>) -> Result<()>;

    /// Model-estimated batch execution time at the card's default clock —
    /// what admission heuristics and the contract suite's monotonicity
    /// check consult. Monotone in N across kernel-count boundaries.
    fn estimate_time_s(&self, gpu: &GpuSpec, workload: &FftWorkload) -> f64;
}

/// Conversion into the type-erased backend handle the `Engine` stores.
/// Exists so call sites keep passing `Arc<Runtime>` (the sim or PJRT
/// concrete runtimes implement `ExecBackend` directly) while new code
/// passes `Arc<dyn ExecBackend>` from [`default_backend`]/[`backend_by_name`].
pub trait IntoBackend {
    fn into_backend(self) -> Arc<dyn ExecBackend>;
}

impl<B: ExecBackend + 'static> IntoBackend for Arc<B> {
    fn into_backend(self) -> Arc<dyn ExecBackend> {
        self
    }
}

impl IntoBackend for Arc<dyn ExecBackend> {
    fn into_backend(self) -> Arc<dyn ExecBackend> {
        self
    }
}

/// Grow `v` to exactly `len` elements without zero-filling. The serving
/// execution paths overwrite every element before any read (`run_rows`,
/// `run_rfft_rows`, `run_conv_rows` write their full output planes), so
/// the memset a plain `resize` performs on growth is pure overhead on
/// the hot path — measurable when mixed-length traffic alternates plane
/// sizes every batch.
#[allow(clippy::uninit_vec)]
pub(crate) fn resize_for_overwrite(v: &mut Vec<f32>, len: usize) {
    v.clear();
    v.reserve(len);
    // SAFETY: capacity >= len after the reserve, and every element in
    // 0..len is written by the planner row kernels before the plane is
    // read (the callers pass these planes straight to run_rows /
    // run_rfft_rows / run_conv_rows, which fully overwrite them).
    unsafe { v.set_len(len) };
}

/// The L2/residency budget the sim planner blocks batches against (and
/// the monolithic-vs-four-step threshold reasoning in DESIGN.md §4e):
/// 4 planes × n × block × width ≤ this.
pub const SIM_L2_BYTES: u64 = 256 * 1024;

// ---------------------------------------------------------------------------
// Sim backend (default build)
// ---------------------------------------------------------------------------

#[cfg(not(feature = "xla"))]
mod sim_impl {
    use super::*;
    use crate::runtime::sim_client::{LoadedModule, Runtime};

    fn sim_caps() -> BackendCaps {
        let modeled = crate::sim::gpu::tesla_v100();
        BackendCaps {
            backend: "sim",
            kinds: vec!["fft", "rfft", "conv", "spectrum", "pipeline"],
            min_n: 1,
            max_n: u64::MAX,
            pow2_only: false,
            precisions: vec![Precision::Fp32, Precision::Fp64],
            split_complex_planes: true,
            locked_clocks: true,
            nvml: false,
            device_mem_bytes: 0, // host execution; cards are simulated
            l2_bytes: SIM_L2_BYTES,
            dev_bw_gbs: modeled.dev_bw_gbs,
            shared_bw_gbs: modeled.shared_bw_gbs,
        }
    }

    impl ExecBackend for Runtime {
        fn name(&self) -> &'static str {
            "sim"
        }

        fn capabilities(&self) -> BackendCaps {
            sim_caps()
        }

        fn manifest(&self) -> &Manifest {
            Runtime::manifest(self)
        }

        fn platform(&self) -> String {
            Runtime::platform(self)
        }

        fn load(&self, name: &str) -> Result<Arc<ExecModule>> {
            let lm = Runtime::load(self, name)?;
            Ok(Arc::new(ExecModule::new(lm.meta.clone(), lm)))
        }

        fn loaded_names(&self) -> Vec<String> {
            Runtime::loaded_names(self)
        }

        fn run_fft_into(
            &self,
            module: &ExecModule,
            re: &[f32],
            im: &[f32],
            out_re: &mut Vec<f32>,
            out_im: &mut Vec<f32>,
        ) -> Result<()> {
            let lm: Arc<LoadedModule> = module.downcast()?;
            lm.run_fft_f32_into(re, im, out_re, out_im)
        }

        fn run_rfft_into(
            &self,
            module: &ExecModule,
            x: &[f32],
            out_re: &mut Vec<f32>,
            out_im: &mut Vec<f32>,
        ) -> Result<()> {
            let lm: Arc<LoadedModule> = module.downcast()?;
            lm.run_rfft_f32_into(x, out_re, out_im)
        }

        fn run_conv_into(&self, module: &ExecModule, x: &[f32], out: &mut Vec<f32>) -> Result<()> {
            let lm: Arc<LoadedModule> = module.downcast()?;
            lm.run_conv_f32_into(x, out)
        }

        fn estimate_time_s(&self, gpu: &GpuSpec, workload: &FftWorkload) -> f64 {
            crate::sim::exec_model::interp_time_power(gpu, workload, gpu.boost_clock_mhz).time_s
        }
    }

    /// The default backend: the hermetic DSP-oracle sim, wrapped so CLI
    /// `--backend sim` and the contract suite have a nameable type.
    pub struct SimBackend {
        rt: Runtime,
    }

    impl SimBackend {
        pub fn new(artifact_dir: &Path) -> Result<Self> {
            Ok(Self {
                rt: Runtime::new(artifact_dir)?,
            })
        }
    }

    impl ExecBackend for SimBackend {
        fn name(&self) -> &'static str {
            "sim"
        }
        fn capabilities(&self) -> BackendCaps {
            self.rt.capabilities()
        }
        fn manifest(&self) -> &Manifest {
            ExecBackend::manifest(&self.rt)
        }
        fn platform(&self) -> String {
            ExecBackend::platform(&self.rt)
        }
        fn load(&self, name: &str) -> Result<Arc<ExecModule>> {
            ExecBackend::load(&self.rt, name)
        }
        fn loaded_names(&self) -> Vec<String> {
            ExecBackend::loaded_names(&self.rt)
        }
        fn run_fft_into(
            &self,
            module: &ExecModule,
            re: &[f32],
            im: &[f32],
            out_re: &mut Vec<f32>,
            out_im: &mut Vec<f32>,
        ) -> Result<()> {
            self.rt.run_fft_into(module, re, im, out_re, out_im)
        }
        fn run_rfft_into(
            &self,
            module: &ExecModule,
            x: &[f32],
            out_re: &mut Vec<f32>,
            out_im: &mut Vec<f32>,
        ) -> Result<()> {
            self.rt.run_rfft_into(module, x, out_re, out_im)
        }
        fn run_conv_into(&self, module: &ExecModule, x: &[f32], out: &mut Vec<f32>) -> Result<()> {
            self.rt.run_conv_into(module, x, out)
        }
        fn estimate_time_s(&self, gpu: &GpuSpec, workload: &FftWorkload) -> f64 {
            self.rt.estimate_time_s(gpu, workload)
        }
    }
}

#[cfg(not(feature = "xla"))]
pub use sim_impl::SimBackend;

// ---------------------------------------------------------------------------
// PJRT/XLA backend (`--features xla`)
// ---------------------------------------------------------------------------

#[cfg(feature = "xla")]
mod xla_impl {
    use super::*;
    use crate::runtime::client::{LoadedModule, Runtime};

    fn xla_caps() -> BackendCaps {
        BackendCaps {
            backend: "xla",
            kinds: vec!["fft", "rfft", "conv", "spectrum", "pipeline"],
            min_n: 1,
            max_n: u64::MAX,
            pow2_only: false,
            precisions: vec![Precision::Fp32, Precision::Fp64],
            split_complex_planes: true,
            // PJRT-CPU exposes neither clock locking nor NVML.
            locked_clocks: false,
            nvml: false,
            device_mem_bytes: 0,
            l2_bytes: 0,
            dev_bw_gbs: 0.0,
            shared_bw_gbs: 0.0,
        }
    }

    impl ExecBackend for Runtime {
        fn name(&self) -> &'static str {
            "xla"
        }

        fn capabilities(&self) -> BackendCaps {
            xla_caps()
        }

        fn manifest(&self) -> &Manifest {
            Runtime::manifest(self)
        }

        fn platform(&self) -> String {
            Runtime::platform(self)
        }

        fn load(&self, name: &str) -> Result<Arc<ExecModule>> {
            let lm = Runtime::load(self, name)?;
            Ok(Arc::new(ExecModule::new(lm.meta.clone(), lm)))
        }

        fn loaded_names(&self) -> Vec<String> {
            Runtime::loaded_names(self)
        }

        fn run_fft_into(
            &self,
            module: &ExecModule,
            re: &[f32],
            im: &[f32],
            out_re: &mut Vec<f32>,
            out_im: &mut Vec<f32>,
        ) -> Result<()> {
            let lm: Arc<LoadedModule> = module.downcast()?;
            lm.run_fft_f32_into(re, im, out_re, out_im)
        }

        fn run_rfft_into(
            &self,
            module: &ExecModule,
            x: &[f32],
            out_re: &mut Vec<f32>,
            out_im: &mut Vec<f32>,
        ) -> Result<()> {
            let lm: Arc<LoadedModule> = module.downcast()?;
            lm.run_rfft_f32_into(x, out_re, out_im)
        }

        fn run_conv_into(&self, module: &ExecModule, x: &[f32], out: &mut Vec<f32>) -> Result<()> {
            let lm: Arc<LoadedModule> = module.downcast()?;
            lm.run_conv_f32_into(x, out)
        }

        fn estimate_time_s(&self, gpu: &GpuSpec, workload: &FftWorkload) -> f64 {
            // No on-device timer hookup; price with the calibrated model
            // (same estimator shape as the sim, so admission heuristics
            // behave identically across backends).
            crate::sim::exec_model::interp_time_power(gpu, workload, gpu.boost_clock_mhz).time_s
        }
    }

    /// The PJRT backend, wrapped for naming parity with [`SimBackend`].
    pub struct XlaBackend {
        rt: Runtime,
    }

    impl XlaBackend {
        pub fn new(artifact_dir: &Path) -> Result<Self> {
            Ok(Self {
                rt: Runtime::new(artifact_dir)?,
            })
        }
    }

    impl ExecBackend for XlaBackend {
        fn name(&self) -> &'static str {
            "xla"
        }
        fn capabilities(&self) -> BackendCaps {
            self.rt.capabilities()
        }
        fn manifest(&self) -> &Manifest {
            ExecBackend::manifest(&self.rt)
        }
        fn platform(&self) -> String {
            ExecBackend::platform(&self.rt)
        }
        fn load(&self, name: &str) -> Result<Arc<ExecModule>> {
            ExecBackend::load(&self.rt, name)
        }
        fn loaded_names(&self) -> Vec<String> {
            ExecBackend::loaded_names(&self.rt)
        }
        fn run_fft_into(
            &self,
            module: &ExecModule,
            re: &[f32],
            im: &[f32],
            out_re: &mut Vec<f32>,
            out_im: &mut Vec<f32>,
        ) -> Result<()> {
            self.rt.run_fft_into(module, re, im, out_re, out_im)
        }
        fn run_rfft_into(
            &self,
            module: &ExecModule,
            x: &[f32],
            out_re: &mut Vec<f32>,
            out_im: &mut Vec<f32>,
        ) -> Result<()> {
            self.rt.run_rfft_into(module, x, out_re, out_im)
        }
        fn run_conv_into(&self, module: &ExecModule, x: &[f32], out: &mut Vec<f32>) -> Result<()> {
            self.rt.run_conv_into(module, x, out)
        }
        fn estimate_time_s(&self, gpu: &GpuSpec, workload: &FftWorkload) -> f64 {
            self.rt.estimate_time_s(gpu, workload)
        }
    }
}

#[cfg(feature = "xla")]
pub use xla_impl::XlaBackend;

// ---------------------------------------------------------------------------
// cuFFT profile-replay backend (all feature sets)
// ---------------------------------------------------------------------------

/// Replays the `cufft/` plan model: capability discovery and timing come
/// from the paper-calibrated cuFFT kernel decomposition (`cufft::plan` +
/// `cufft::profile`), while the numerics run through the same planned DSP
/// engine as the sim — the stand-in for a real cuFFT device backend until
/// one is linked. fft-only (the plan model prices C2C transforms), n >= 2
/// (the model's floor).
pub struct CufftProfileBackend {
    manifest: Manifest,
    gpu: GpuSpec,
    cache: std::sync::RwLock<std::collections::HashMap<String, Arc<ExecModule>>>,
}

/// The cufft backend's module payload: the replayed kernel decomposition
/// plus the execution plan for the oracle numerics.
struct CufftModule {
    cufft_plan: crate::cufft::plan::FftPlan,
    exec_plan: Arc<crate::dsp::planner::FftPlan>,
}

impl CufftProfileBackend {
    /// Against an artifact directory (manifest.tsv or the synthetic set),
    /// keeping only the entries the plan model can price (kind `fft`).
    pub fn new(artifact_dir: &Path) -> Result<Self> {
        Self::with_gpu(artifact_dir, crate::sim::gpu::tesla_v100())
    }

    /// Same, replaying traces for a specific modeled card.
    pub fn with_gpu(artifact_dir: &Path, gpu: GpuSpec) -> Result<Self> {
        let mut manifest = if artifact_dir.join("manifest.tsv").exists() {
            Manifest::load(artifact_dir)?
        } else {
            Manifest::synthetic(artifact_dir)
        };
        manifest.entries.retain(|_, a| a.kind == "fft" && a.n >= 2);
        Ok(Self {
            manifest,
            gpu,
            cache: std::sync::RwLock::new(std::collections::HashMap::new()),
        })
    }

    fn cache_read(
        &self,
    ) -> std::sync::RwLockReadGuard<'_, std::collections::HashMap<String, Arc<ExecModule>>> {
        self.cache.read().unwrap_or_else(std::sync::PoisonError::into_inner)
    }

    fn cache_write(
        &self,
    ) -> std::sync::RwLockWriteGuard<'_, std::collections::HashMap<String, Arc<ExecModule>>> {
        self.cache.write().unwrap_or_else(std::sync::PoisonError::into_inner)
    }

    fn unsupported(&self, kind: &str, n: u64) -> anyhow::Error {
        BackendError::Unsupported {
            backend: "cufft-profile",
            kind: kind.to_string(),
            n,
        }
        .into()
    }

    /// The replayed NVVP-style kernel profile for one manifest length at
    /// one clock (what `fftsweep roofline` prints per backend).
    pub fn profile(&self, n: u64, f_mhz: f64) -> crate::cufft::profile::PlanProfile {
        let workload = FftWorkload::new(n, Precision::Fp32, self.gpu.working_set_bytes);
        let plan = crate::cufft::plan::plan(n, Precision::Fp32);
        crate::cufft::profile::profile_plan(&self.gpu, &workload, &plan, f_mhz)
    }
}

impl ExecBackend for CufftProfileBackend {
    fn name(&self) -> &'static str {
        "cufft-profile"
    }

    fn capabilities(&self) -> BackendCaps {
        BackendCaps {
            backend: "cufft-profile",
            kinds: vec!["fft"],
            min_n: 2,
            max_n: u64::MAX,
            pow2_only: false,
            precisions: vec![Precision::Fp32, Precision::Fp64],
            split_complex_planes: true,
            locked_clocks: true,
            nvml: false,
            device_mem_bytes: self.gpu.mem_bytes,
            l2_bytes: SIM_L2_BYTES,
            dev_bw_gbs: self.gpu.dev_bw_gbs,
            shared_bw_gbs: self.gpu.shared_bw_gbs,
        }
    }

    fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn platform(&self) -> String {
        format!("cufft-profile replay ({} plan model)", self.gpu.name)
    }

    fn load(&self, name: &str) -> Result<Arc<ExecModule>> {
        if let Some(m) = self.cache_read().get(name) {
            return Ok(m.clone());
        }
        let meta = self.manifest.get(name)?.clone();
        if meta.kind != "fft" || !self.capabilities().supports_len(meta.n) {
            return Err(self.unsupported(&meta.kind, meta.n));
        }
        let payload = Arc::new(CufftModule {
            cufft_plan: crate::cufft::plan::plan(meta.n, Precision::Fp32),
            exec_plan: crate::dsp::planner::plan_for(meta.n as usize),
        });
        let module = Arc::new(ExecModule::new(meta, payload));
        Ok(self
            .cache_write()
            .entry(name.to_string())
            .or_insert(module)
            .clone())
    }

    fn loaded_names(&self) -> Vec<String> {
        let mut names: Vec<String> = self.cache_read().keys().cloned().collect();
        names.sort();
        names
    }

    fn run_fft_into(
        &self,
        module: &ExecModule,
        re: &[f32],
        im: &[f32],
        out_re: &mut Vec<f32>,
        out_im: &mut Vec<f32>,
    ) -> Result<()> {
        let m: Arc<CufftModule> = module.downcast()?;
        let n = module.meta.n as usize;
        let batch = module.meta.batch as usize;
        anyhow::ensure!(
            re.len() == batch * n && im.len() == batch * n,
            "module '{}' wants {}x{} input planes, got {}/{}",
            module.meta.name,
            batch,
            n,
            re.len(),
            im.len()
        );
        debug_assert_eq!(m.cufft_plan.n, module.meta.n);
        resize_for_overwrite(out_re, batch * n);
        resize_for_overwrite(out_im, batch * n);
        crate::dsp::planner::run_rows(
            &m.exec_plan,
            crate::dsp::planner::Direction::Forward,
            re,
            im,
            batch,
            out_re,
            out_im,
        );
        Ok(())
    }

    fn run_rfft_into(
        &self,
        module: &ExecModule,
        _x: &[f32],
        _out_re: &mut Vec<f32>,
        _out_im: &mut Vec<f32>,
    ) -> Result<()> {
        Err(self.unsupported("rfft", module.meta.n))
    }

    fn run_conv_into(&self, module: &ExecModule, _x: &[f32], _out: &mut Vec<f32>) -> Result<()> {
        Err(self.unsupported("conv", module.meta.n))
    }

    fn estimate_time_s(&self, gpu: &GpuSpec, workload: &FftWorkload) -> f64 {
        // Replay the NVVP-style trace: per-kernel times from the plan
        // model at the card's default clock, summed.
        self.profile_for(gpu, workload).kernels.iter().map(|k| k.time_s).sum()
    }
}

impl CufftProfileBackend {
    fn profile_for(
        &self,
        gpu: &GpuSpec,
        workload: &FftWorkload,
    ) -> crate::cufft::profile::PlanProfile {
        let plan = crate::cufft::plan::plan(workload.n, workload.precision);
        crate::cufft::profile::profile_plan(gpu, workload, &plan, gpu.boost_clock_mhz)
    }
}

// ---------------------------------------------------------------------------
// Construction helpers
// ---------------------------------------------------------------------------

/// The build's default backend against an artifact directory: the sim
/// oracle, or PJRT under `--features xla`.
pub fn default_backend(artifact_dir: &Path) -> Result<Arc<dyn ExecBackend>> {
    #[cfg(not(feature = "xla"))]
    {
        Ok(Arc::new(SimBackend::new(artifact_dir)?))
    }
    #[cfg(feature = "xla")]
    {
        Ok(Arc::new(XlaBackend::new(artifact_dir)?))
    }
}

/// Backend by CLI name (`--backend sim|xla|cufft-profile`). The default
/// name resolves per build; asking for a backend the build does not carry
/// is a typed failure, not a silent substitution.
pub fn backend_by_name(name: &str, artifact_dir: &Path) -> Result<Arc<dyn ExecBackend>> {
    match name {
        "default" => default_backend(artifact_dir),
        "cufft-profile" => Ok(Arc::new(CufftProfileBackend::new(artifact_dir)?)),
        #[cfg(not(feature = "xla"))]
        "sim" => Ok(Arc::new(SimBackend::new(artifact_dir)?)),
        #[cfg(feature = "xla")]
        "xla" => Ok(Arc::new(XlaBackend::new(artifact_dir)?)),
        other => anyhow::bail!(
            "unknown backend '{other}' (this build carries: {})",
            compiled_backend_names().join(", ")
        ),
    }
}

/// The backends this feature set compiled in.
pub fn compiled_backend_names() -> Vec<&'static str> {
    #[cfg(not(feature = "xla"))]
    {
        vec!["sim", "cufft-profile"]
    }
    #[cfg(feature = "xla")]
    {
        vec!["xla", "cufft-profile"]
    }
}

#[cfg(all(test, not(feature = "xla")))]
mod tests {
    use super::*;
    use crate::sim::gpu::tesla_v100;
    use crate::util::rng::Rng;

    fn dir() -> &'static Path {
        Path::new("/nonexistent-artifacts")
    }

    #[test]
    fn sim_backend_caps_cover_synthetic_manifest() {
        let b = SimBackend::new(dir()).unwrap();
        let caps = b.capabilities();
        for meta in b.manifest().entries.values() {
            assert!(
                caps.supports(&meta.kind, meta.n, Precision::Fp32),
                "caps refuse advertised artifact {}",
                meta.name
            );
        }
        assert!(!caps.supports_len(0), "n=0 must stay refused");
        assert!(caps.summary().contains("backend sim"));
    }

    #[test]
    fn trait_run_matches_module_run_bit_identically() {
        let b = SimBackend::new(dir()).unwrap();
        let m = ExecBackend::load(&b, "fft_f32_n1024_b64").unwrap();
        let total = (m.meta.batch * m.meta.n) as usize;
        let mut rng = Rng::new(7);
        let re: Vec<f32> = (0..total).map(|_| rng.gauss() as f32).collect();
        let im: Vec<f32> = (0..total).map(|_| rng.gauss() as f32).collect();
        let (mut a, mut bb) = (Vec::new(), Vec::new());
        b.run_fft_into(&m, &re, &im, &mut a, &mut bb).unwrap();
        // vs the legacy module path on a fresh runtime
        let rt = crate::runtime::sim_client::Runtime::new(dir()).unwrap();
        let lm = rt.load("fft_f32_n1024_b64").unwrap();
        let (mut c, mut d) = (Vec::new(), Vec::new());
        lm.run_fft_f32_into(&re, &im, &mut c, &mut d).unwrap();
        assert_eq!(a, c);
        assert_eq!(bb, d);
    }

    #[test]
    fn cufft_profile_backend_refuses_non_fft() {
        let b = CufftProfileBackend::new(dir()).unwrap();
        // manifest filtered: only fft entries remain
        assert!(b.manifest().entries.values().all(|a| a.kind == "fft"));
        // a conv run through a (stolen) fft module is a typed refusal
        let m = ExecBackend::load(&b, "fft_f32_n1024_b64").unwrap();
        let x = vec![0.0f32; (m.meta.batch * m.meta.n) as usize];
        let mut out = Vec::new();
        let err = b.run_conv_into(&m, &x, &mut out).unwrap_err();
        assert!(
            matches!(
                err.downcast_ref::<BackendError>(),
                Some(BackendError::Unsupported { backend: "cufft-profile", .. })
            ),
            "want typed BackendError, got: {err:#}"
        );
    }

    #[test]
    fn cufft_profile_runs_fft_numerics() {
        let b = CufftProfileBackend::new(dir()).unwrap();
        let m = ExecBackend::load(&b, "fft_f32_n256_b256").unwrap();
        let n = m.meta.n as usize;
        let total = (m.meta.batch * m.meta.n) as usize;
        let mut rng = Rng::new(5);
        let re: Vec<f32> = (0..total).map(|_| rng.gauss() as f32).collect();
        let im: Vec<f32> = (0..total).map(|_| rng.gauss() as f32).collect();
        let (mut o_re, mut o_im) = (Vec::new(), Vec::new());
        b.run_fft_into(&m, &re, &im, &mut o_re, &mut o_im).unwrap();
        // Parseval on row 0
        let e_time: f64 = (0..n)
            .map(|i| (re[i] as f64).powi(2) + (im[i] as f64).powi(2))
            .sum();
        let e_freq: f64 = (0..n)
            .map(|i| (o_re[i] as f64).powi(2) + (o_im[i] as f64).powi(2))
            .sum::<f64>()
            / n as f64;
        assert!((e_time - e_freq).abs() < 1e-4 * e_time.max(1.0));
    }

    #[test]
    fn estimates_rise_across_kernel_count_boundaries() {
        let g = tesla_v100();
        let sim = SimBackend::new(dir()).unwrap();
        let cf = CufftProfileBackend::new(dir()).unwrap();
        for backend in [&sim as &dyn ExecBackend, &cf as &dyn ExecBackend] {
            let t: Vec<f64> = [1024u64, 1 << 14, 1 << 21]
                .iter()
                .map(|&n| {
                    backend.estimate_time_s(
                        &g,
                        &FftWorkload::new(n, Precision::Fp32, g.working_set_bytes),
                    )
                })
                .collect();
            assert!(
                t[0] < t[1] && t[1] < t[2],
                "{}: estimate not monotone across kernel boundaries: {t:?}",
                backend.name()
            );
        }
    }

    #[test]
    fn into_backend_accepts_concrete_and_erased_arcs() {
        let concrete: Arc<crate::runtime::sim_client::Runtime> =
            Arc::new(crate::runtime::sim_client::Runtime::new(dir()).unwrap());
        let erased: Arc<dyn ExecBackend> = concrete.clone();
        assert_eq!(concrete.into_backend().name(), "sim");
        assert_eq!(erased.into_backend().name(), "sim");
    }

    #[test]
    fn resize_for_overwrite_reuses_capacity() {
        let mut v = vec![1.0f32; 64];
        let ptr = v.as_ptr();
        resize_for_overwrite(&mut v, 32);
        assert_eq!(v.len(), 32);
        assert_eq!(v.as_ptr(), ptr, "shrink must not reallocate");
        resize_for_overwrite(&mut v, 64);
        assert_eq!(v.len(), 64);
        assert_eq!(v.as_ptr(), ptr, "regrow within capacity must not reallocate");
    }
}
