//! Artifact manifest: the TSV index `python/compile/aot.py` writes next to
//! the HLO text files in `artifacts/`.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

/// One AOT-lowered module as described by the manifest.
#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    pub name: String,
    pub file: PathBuf,
    /// "fft" | "rfft" | "conv" | "spectrum" | "pipeline"
    pub kind: String,
    /// Transform length — for `conv`, the signal length per row (the FIR
    /// tap count rides in `harmonics`).
    pub n: u64,
    pub batch: u64,
    pub dtype: String,
    pub harmonics: u64,
    /// Raw input spec string, e.g. "f32:4x16384;f32:4x16384".
    pub inputs: String,
    pub n_outputs: usize,
    pub digest: String,
}

impl ArtifactMeta {
    /// Parsed input shapes: (dtype, dims) per parameter.
    pub fn input_shapes(&self) -> Vec<(String, Vec<u64>)> {
        self.inputs
            .split(';')
            .filter(|s| !s.is_empty())
            .map(|s| {
                let (ty, dims) = s.split_once(':').unwrap_or(("f32", s));
                let dims = dims
                    .split('x')
                    .filter_map(|d| d.parse().ok())
                    .collect::<Vec<u64>>();
                (ty.to_string(), dims)
            })
            .collect()
    }

    pub fn elements_per_input(&self) -> u64 {
        self.batch * self.n
    }
}

/// The manifest: name → ArtifactMeta, plus the base directory.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub entries: BTreeMap<String, ArtifactMeta>,
}

impl Manifest {
    /// Load `<dir>/manifest.tsv`.
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.tsv");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        Self::parse(dir, &text)
    }

    pub fn parse(dir: &Path, text: &str) -> Result<Self> {
        let mut lines = text.lines();
        let header = lines.next().context("empty manifest")?;
        let cols: Vec<&str> = header.split('\t').collect();
        if cols.first() != Some(&"name") {
            bail!("unexpected manifest header: {header}");
        }
        let mut entries = BTreeMap::new();
        for (i, line) in lines.enumerate() {
            if line.trim().is_empty() {
                continue;
            }
            let f: Vec<&str> = line.split('\t').collect();
            if f.len() != 10 {
                bail!("manifest line {} has {} fields, want 10", i + 2, f.len());
            }
            let meta = ArtifactMeta {
                name: f[0].to_string(),
                file: dir.join(f[1]),
                kind: f[2].to_string(),
                n: f[3].parse().context("bad n")?,
                batch: f[4].parse().context("bad batch")?,
                dtype: f[5].to_string(),
                harmonics: f[6].parse().context("bad harmonics")?,
                inputs: f[7].to_string(),
                n_outputs: f[8].parse().context("bad n_outputs")?,
                digest: f[9].to_string(),
            };
            entries.insert(meta.name.clone(), meta);
        }
        Ok(Self { dir: dir.to_path_buf(), entries })
    }

    pub fn get(&self, name: &str) -> Result<&ArtifactMeta> {
        self.entries
            .get(name)
            .with_context(|| format!("artifact '{name}' not in manifest"))
    }

    /// All artifacts of a kind, ordered by name.
    pub fn of_kind(&self, kind: &str) -> Vec<&ArtifactMeta> {
        self.entries.values().filter(|a| a.kind == kind).collect()
    }

    /// The pipeline artifact with a specific harmonic count.
    pub fn pipeline(&self, harmonics: u64) -> Result<&ArtifactMeta> {
        self.entries
            .values()
            .find(|a| a.kind == "pipeline" && a.harmonics == harmonics)
            .with_context(|| format!("no pipeline artifact with h={harmonics}"))
    }

    /// The FFT artifact for (n, dtype), if lowered.
    pub fn fft(&self, n: u64, dtype: &str) -> Result<&ArtifactMeta> {
        self.entries
            .values()
            .find(|a| a.kind == "fft" && a.n == n && a.dtype == dtype)
            .with_context(|| format!("no fft artifact n={n} dtype={dtype}"))
    }

    /// The conv (filterbank) artifact for (n, taps), if present — taps are
    /// carried in the harmonics field.
    pub fn conv(&self, n: u64, taps: u64) -> Result<&ArtifactMeta> {
        self.entries
            .values()
            .find(|a| a.kind == "conv" && a.n == n && a.harmonics == taps)
            .with_context(|| format!("no conv artifact n={n} taps={taps}"))
    }

    /// Default artifact directory: $FFTSWEEP_ARTIFACTS or ./artifacts.
    pub fn default_dir() -> PathBuf {
        std::env::var("FFTSWEEP_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }

    /// Digest marker for entries that exist only in the simulated backend.
    pub const SIMULATED_DIGEST: &'static str = "simulated";

    /// The standard artifact set as a synthetic manifest (no files on
    /// disk) — what the simulated runtime backend serves when `aot.py`
    /// never ran. Mirrors the names/batches `make artifacts` produces,
    /// plus the non-power-of-two and real-input entries the planner's
    /// mixed-radix/Bluestein/rFFT paths serve (channelizer-shaped traffic).
    pub fn synthetic(dir: &Path) -> Self {
        let mut entries = BTreeMap::new();
        let mut add = |name: String,
                       kind: &str,
                       n: u64,
                       batch: u64,
                       dtype: &str,
                       harmonics: u64,
                       inputs: String,
                       n_outputs: usize| {
            let meta = ArtifactMeta {
                file: dir.join(format!("{name}.hlo.txt")),
                kind: kind.to_string(),
                n,
                batch,
                dtype: dtype.to_string(),
                harmonics,
                inputs,
                n_outputs,
                digest: Self::SIMULATED_DIGEST.to_string(),
                name: name.clone(),
            };
            entries.insert(name, meta);
        };
        fn c2c(dtype: &str, batch: u64, n: u64) -> String {
            format!("{dtype}:{batch}x{n};{dtype}:{batch}x{n}")
        }
        // n=1000 (2³·5³) and n=1536 (2⁹·3) are the issue's off-grid serving
        // lengths (mixed-radix plans, routable like any power of two);
        // n=262144 (2¹⁸) is the large-N tier — past the L2 budget the
        // planner compiles it to the cache-blocked four-step path.
        let fft_set = [
            (256u64, 256u64),
            (1000, 64),
            (1024, 64),
            (1536, 64),
            (4096, 16),
            (16384, 4),
            (262144, 2),
        ];
        for (n, batch) in fft_set {
            add(
                format!("fft_f32_n{n}_b{batch}"),
                "fft",
                n,
                batch,
                "f32",
                0,
                c2c("f32", batch, n),
                2,
            );
        }
        add("fft_f64_n1024_b64".into(), "fft", 1024, 64, "f64", 0, c2c("f64", 64, 1024), 2);
        // Real-input transform: one (batch, n) plane in, two (batch, n/2+1)
        // spectrum planes out.
        add(
            "rfft_f32_n4096_b16".into(),
            "rfft",
            4096,
            16,
            "f32",
            0,
            "f32:16x4096".to_string(),
            2,
        );
        // FFT-domain FIR filterbank rows (overlap-save): one (batch, n)
        // real plane in, one filtered plane out; the Hamming tap count
        // rides in the harmonics field (`planner::synthetic_kernel`).
        for (n, taps, batch) in [(4096u64, 129u64, 16u64), (262144, 257, 2)] {
            add(
                format!("conv_f32_n{n}_t{taps}_b{batch}"),
                "conv",
                n,
                batch,
                "f32",
                taps,
                format!("f32:{batch}x{n}"),
                1,
            );
        }
        add(
            "spectrum_f32_n4096_b16".into(),
            "spectrum",
            4096,
            16,
            "f32",
            0,
            c2c("f32", 16, 4096),
            1,
        );
        for h in [2u64, 4, 8, 16, 32] {
            add(
                format!("pipeline_n16384_h{h}"),
                "pipeline",
                16384,
                4,
                "f32",
                h,
                c2c("f32", 4, 16384),
                3,
            );
        }
        Self { dir: dir.to_path_buf(), entries }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "name\tfile\tkind\tn\tbatch\tdtype\tharmonics\tinputs\tn_outputs\tsha256_16\n\
        fft_f32_n1024_b64\tfft_f32_n1024_b64.hlo.txt\tfft\t1024\t64\tf32\t0\tf32:64x1024;f32:64x1024\t2\tdeadbeef00000000\n\
        pipeline_n16384_h8\tpipeline_n16384_h8.hlo.txt\tpipeline\t16384\t4\tf32\t8\tf32:4x16384;f32:4x16384\t3\tcafebabe00000000\n";

    #[test]
    fn parse_sample() {
        let m = Manifest::parse(Path::new("/tmp/a"), SAMPLE).unwrap();
        assert_eq!(m.entries.len(), 2);
        let f = m.get("fft_f32_n1024_b64").unwrap();
        assert_eq!(f.n, 1024);
        assert_eq!(f.batch, 64);
        assert_eq!(f.n_outputs, 2);
        assert_eq!(f.file, Path::new("/tmp/a/fft_f32_n1024_b64.hlo.txt"));
    }

    #[test]
    fn input_shapes_parse() {
        let m = Manifest::parse(Path::new("."), SAMPLE).unwrap();
        let f = m.get("fft_f32_n1024_b64").unwrap();
        let shapes = f.input_shapes();
        assert_eq!(shapes.len(), 2);
        assert_eq!(shapes[0], ("f32".to_string(), vec![64, 1024]));
    }

    #[test]
    fn kind_and_lookup_helpers() {
        let m = Manifest::parse(Path::new("."), SAMPLE).unwrap();
        assert_eq!(m.of_kind("fft").len(), 1);
        assert!(m.pipeline(8).is_ok());
        assert!(m.pipeline(4).is_err());
        assert!(m.fft(1024, "f32").is_ok());
        assert!(m.fft(1024, "f64").is_err());
    }

    #[test]
    fn synthetic_manifest_matches_real_shape() {
        let m = Manifest::synthetic(Path::new("/nonexistent"));
        assert!(m.of_kind("fft").len() >= 4);
        assert_eq!(m.of_kind("pipeline").len(), 5);
        let f = m.fft(1024, "f32").unwrap();
        assert_eq!(f.batch, 64);
        assert_eq!(f.input_shapes()[0], ("f32".to_string(), vec![64, 1024]));
        assert_eq!(f.digest, Manifest::SIMULATED_DIGEST);
        assert!(m.pipeline(8).is_ok());
        assert!(m.fft(1024, "f64").is_ok());
    }

    #[test]
    fn synthetic_manifest_has_non_pow2_and_rfft_entries() {
        let m = Manifest::synthetic(Path::new("/nonexistent"));
        for n in [1000u64, 1536] {
            let f = m.fft(n, "f32").unwrap();
            assert_eq!(f.batch, 64, "n={n}");
            assert_eq!(f.input_shapes().len(), 2, "n={n}");
        }
        let r = m.get("rfft_f32_n4096_b16").unwrap();
        assert_eq!(r.kind, "rfft");
        assert_eq!(r.n_outputs, 2);
        let shapes = r.input_shapes();
        assert_eq!(shapes.len(), 1, "rfft takes one real plane");
        assert_eq!(shapes[0], ("f32".to_string(), vec![16, 4096]));
        // rfft entries must NOT enter the (complex) fft routing table
        assert!(m.of_kind("fft").iter().all(|a| a.kind == "fft"));
    }

    #[test]
    fn synthetic_manifest_has_large_n_and_conv_entries() {
        let m = Manifest::synthetic(Path::new("/nonexistent"));
        // The 2^18 four-step serving entry.
        let big = m.fft(262144, "f32").unwrap();
        assert_eq!(big.batch, 2);
        assert_eq!(big.input_shapes()[0], ("f32".to_string(), vec![2, 262144]));
        // Conv entries: one real plane in, one filtered plane out, taps in
        // the harmonics field.
        for (n, taps) in [(4096u64, 129u64), (262144, 257)] {
            let c = m.conv(n, taps).unwrap();
            assert_eq!(c.kind, "conv");
            assert_eq!(c.harmonics, taps);
            assert_eq!(c.n_outputs, 1);
            let shapes = c.input_shapes();
            assert_eq!(shapes.len(), 1, "conv takes one real plane");
            assert_eq!(shapes[0].1, vec![c.batch, n]);
        }
        assert!(m.conv(4096, 9).is_err());
        // conv entries must not leak into the complex fft routing table
        assert!(m.of_kind("fft").iter().all(|a| a.kind == "fft"));
    }

    #[test]
    fn rejects_malformed() {
        assert!(Manifest::parse(Path::new("."), "bogus\theader\n").is_err());
        assert!(Manifest::parse(Path::new("."), "").is_err());
        let short = "name\tfile\tkind\tn\tbatch\tdtype\tharmonics\tinputs\tn_outputs\tsha256_16\nonly\tthree\tfields\n";
        assert!(Manifest::parse(Path::new("."), short).is_err());
    }
}
