//! Real-time provisioning planner (sections 2.3 / 6.1): for every GPU,
//! compare running a real-time FFT pipeline at boost vs the mean-optimal
//! clock — slowdown, extra hardware needed to stay real-time, and the
//! fleet-level energy change. The "capital vs operational cost" trade-off
//! the paper discusses, as a tool.
//!
//! Run:  cargo run --release --example realtime_planner -- [--n 16384]

use anyhow::Result;

use fftsweep::analysis::{mean_optimal_mhz, optima};
use fftsweep::harness::sweep::{sweep_gpu, SweepConfig};
use fftsweep::harness::Protocol;
use fftsweep::pipeline::realtime;
use fftsweep::sim::gpu::all_gpus;
use fftsweep::sim::run_batch;
use fftsweep::types::{FftWorkload, Precision};
use fftsweep::util::cliargs::Args;
use fftsweep::util::table::fnum;

fn main() -> Result<()> {
    let args = Args::from_env();
    let n = args.u64_or("n", 16384);

    println!("real-time planning for a pipeline dominated by N={n} FP32 FFTs");
    println!("(assumes the boost-clock configuration exactly meets real time, S = 1)\n");
    println!(
        "{:<12} | {:>9} | {:>9} | {:>7} | {:>6} | {:>12} | {:>12}",
        "GPU", "boost MHz", "tuned MHz", "dT %", "cards", "fleet energy", "verdict"
    );

    let cfg = SweepConfig {
        lengths: vec![1024, n, 262144],
        freq_stride: 8,
        protocol: Protocol::default(),
    };
    for gpu in all_gpus() {
        let sweep = sweep_gpu(&gpu, Precision::Fp32, &cfg);
        let mean_opt = mean_optimal_mhz(&gpu, &optima(&gpu, &sweep));
        let w = FftWorkload::new(n, Precision::Fp32, gpu.working_set_bytes);
        let boost = run_batch(&gpu, &w, gpu.boost_clock_mhz);
        let tuned = run_batch(&gpu, &w, mean_opt);
        let slowdown = tuned.timing.total_s / boost.timing.total_s;
        let energy_ratio = tuned.energy_j / boost.energy_j;
        let t = realtime::tradeoff(slowdown, energy_ratio);
        let assess = realtime::assess(1.0, slowdown);
        let verdict = if assess.realtime {
            "keep fleet"
        } else if t.fleet_energy_ratio < 1.0 {
            "grow fleet"
        } else {
            "stay boost"
        };
        println!(
            "{:<12} | {:>9} | {:>9} | {:>7} | {:>6} | {:>11}% | {:>12}",
            gpu.name,
            fnum(gpu.boost_clock_mhz, 0),
            fnum(mean_opt, 0),
            fnum((slowdown - 1.0) * 100.0, 1),
            t.cards_needed,
            fnum(t.fleet_energy_ratio * 100.0, 1),
            verdict
        );
    }
    println!(
        "\nreading: V100-class cards trade <5% time for ~30-45% energy (keep the fleet);\n\
         the Jetson Nano needs ~2x the boards for its best efficiency (the paper's +60% hardware)."
    );
    Ok(())
}
