//! Frequency sweep for one GPU/precision: the measurement campaign of
//! section 4 in miniature — sweep the clock table, find per-length optima,
//! the mean optimal clock (Table 3), and write the Fig 9-16 CSVs.
//!
//! Run:  cargo run --release --example frequency_sweep -- [--gpu v100] [--precision fp32]

use std::path::PathBuf;

use anyhow::{Context, Result};

use fftsweep::analysis::figures;
use fftsweep::analysis::{mean_optimal_mhz, optima};
use fftsweep::harness::sweep::{sweep_gpu, SweepConfig};
use fftsweep::sim::gpu::gpu_by_name;
use fftsweep::types::Precision;
use fftsweep::util::cliargs::Args;
use fftsweep::util::table::fnum;

fn main() -> Result<()> {
    let args = Args::from_env();
    let gpu = gpu_by_name(args.str_or("gpu", "v100")).context("unknown gpu")?;
    let precision = Precision::parse(args.str_or("precision", "fp32")).context("bad precision")?;
    let out = PathBuf::from(args.str_or("out", "results/example_sweep"));

    let mut cfg = SweepConfig::default();
    cfg.freq_stride = args.usize_or("freq-stride", 8);
    if args.has("quick") {
        cfg = SweepConfig::quick();
    }

    println!("sweeping {} {} over {} lengths…", gpu.name, precision, cfg.lengths.len());
    let sweep = sweep_gpu(&gpu, precision, &cfg);
    let pts = optima(&gpu, &sweep);
    let mean_opt = mean_optimal_mhz(&gpu, &pts);

    println!("\nper-length optima:");
    for p in &pts {
        println!(
            "  N={:>8}: f_opt {:>7} MHz ({:>5}% of boost), Ief(boost) {:>6}, dT {:>6}%{}",
            p.n,
            fnum(p.f_opt_mhz, 0),
            fnum(p.frac_of_boost * 100.0, 1),
            fnum(p.eff_increase_vs_boost, 3),
            fnum(p.time_increase * 100.0, 2),
            if p.bluestein { "  [bluestein]" } else { "" }
        );
    }
    println!("\nmean optimal clock: {} MHz", fnum(mean_opt, 1));

    std::fs::create_dir_all(&out)?;
    figures::figure9_to_14(&gpu, &sweep).write_csv(&out.join("fig9_14.csv"))?;
    let (_, f15) = figures::figure15_16(&gpu, &sweep);
    f15.write_csv(&out.join("fig15_16.csv"))?;
    figures::figure17_18(&gpu, &sweep).write_csv(&out.join("fig17_18.csv"))?;
    figures::figure3(&gpu, &sweep).write_csv(&out.join("fig3.csv"))?;
    figures::figure6(&gpu, &sweep).write_csv(&out.join("fig6.csv"))?;
    figures::figure8(&gpu, &sweep).write_csv(&out.join("fig8.csv"))?;
    println!("CSVs written under {out:?}");
    Ok(())
}
