//! End-to-end driver (the EXPERIMENTS.md validation run): the paper's
//! section-5.3 pulsar-search pipeline, running REAL compute through all
//! three layers — synthetic pulsar time series (rust) → AOT Pallas/JAX
//! pipeline artifacts (FFT → power spectrum → mean/std → harmonic sum)
//! executed by the PJRT runtime — while the simulated V100 + NVML
//! controller account the DVFS energy story (Table 4 + Fig 19).
//!
//! Run:  make artifacts && cargo run --release --example pulsar_pipeline

use std::time::Instant;

use anyhow::Result;

use fftsweep::dsp;
use fftsweep::governor::GovernorKind;
use fftsweep::pipeline::{run_pipeline_at, table4};
use fftsweep::runtime::{Manifest, Runtime};
use fftsweep::sim::gpu::tesla_v100;
use fftsweep::util::rng::Rng;
use fftsweep::util::table::fnum;

fn main() -> Result<()> {
    let rt = Runtime::new(&Manifest::default_dir())?;
    let gpu = tesla_v100();
    let mut rng = Rng::new(0xBEEF);

    println!("=== end-to-end pulsar search (real compute via PJRT) ===");
    let n = 16384usize;
    let params = dsp::PulsarParams {
        fundamental_bin: 321,
        harmonics: 8,
        amplitude: 0.30,
    };
    let mut detections = 0;
    let mut wall_us_total = 0u128;
    for h in [2u64, 4, 8, 16, 32] {
        let module = rt.load(&format!("pipeline_n16384_h{h}"))?;
        let batch = module.meta.batch as usize;
        let mut re = Vec::with_capacity(batch * n);
        let mut im = Vec::with_capacity(batch * n);
        for _ in 0..batch {
            let x = dsp::pulsar_time_series(n, &params, &mut rng);
            for c in &x {
                re.push(c.re as f32);
                im.push(c.im as f32);
            }
        }
        let t0 = Instant::now();
        let out = module.run_f32(&[&re, &im])?;
        let wall = t0.elapsed();
        wall_us_total += wall.as_micros();
        let n_out = n / h as usize;
        let mut found = 0;
        let mut best_snr: f64 = 0.0;
        for b in 0..batch {
            if let Some(det) = dsp::detect_peak(&out[0][b * n_out..(b + 1) * n_out], 8) {
                if det.bin == params.fundamental_bin {
                    found += 1;
                    best_snr = best_snr.max(det.snr);
                }
            }
        }
        detections += found;
        println!(
            "h={h:>2}: {found}/{batch} pulsars recovered at bin {} (best S/N {:.1}), {} per {batch}-row batch",
            params.fundamental_bin,
            best_snr,
            format!("{:.2} ms", wall.as_secs_f64() * 1e3),
        );
    }
    println!(
        "total PJRT wall time {:.1} ms; detections {detections}/20",
        wall_us_total as f64 / 1e3
    );
    // Harmonic summing is the point: with only h=2 of the 8 injected
    // harmonics collected, recovery is marginal; at h=8 it is certain, and
    // beyond the pulsar's harmonic content S/N falls again (noise-only
    // bins enter the sum) — exactly the paper's motivation for tuning H.
    assert!(detections >= 14, "pipeline lost the pulsar ({detections}/20)");

    println!("\n=== Table 4 reproduction (simulated V100, N=5e5, FFT @ 945 MHz via NVML) ===");
    println!("{:>9} | {:>12} | {:>12} | paper", "harmonics", "FFT time [%]", "eff increase");
    let paper = [(2u64, 60.85, 1.291), (4, 58.56, 1.290), (8, 55.92, 1.267), (16, 53.73, 1.260), (32, 51.34, 1.240)];
    for (row, (ph, pfft, peff)) in
        table4(&gpu, 500_000, &GovernorKind::FixedClock(945.0)).iter().zip(paper)
    {
        assert_eq!(row.harmonics, ph);
        println!(
            "{:>9} | {:>12} | {:>12} | {:>5}% / {}",
            row.harmonics,
            fnum(row.fft_time_pct, 2),
            fnum(row.eff_increase, 3),
            fnum(pfft, 2),
            fnum(peff, 3),
        );
    }

    println!("\n=== Fig 19: pipeline power/clock trace (simulated) ===");
    let run = run_pipeline_at(&gpu, 500_000, 8, Some(945.0));
    let mut t = 0.0;
    for s in &run.stages {
        println!(
            "  t={:>8} ms  {:<14} clock={:>6} MHz  P={:>6} W",
            fnum(t * 1e3, 2),
            s.name,
            fnum(s.clock_mhz, 0),
            fnum(s.energy_j / s.time_s.max(1e-12), 1)
        );
        t += s.time_s;
    }
    println!("pulsar_pipeline OK");
    Ok(())
}
