//! Quickstart: load an AOT-compiled Pallas FFT artifact, execute it through
//! the PJRT runtime, validate the numerics, and estimate the DVFS energy
//! saving the paper's result predicts for this batch.
//!
//! Run:  make artifacts && cargo run --release --example quickstart

use anyhow::Result;

use fftsweep::dsp;
use fftsweep::runtime::{Manifest, Runtime};
use fftsweep::sim::gpu::tesla_v100;
use fftsweep::sim::run_batch;
use fftsweep::types::{FftWorkload, Precision};
use fftsweep::util::rng::Rng;

fn main() -> Result<()> {
    // 1. Bring up the runtime against the artifacts directory.
    let rt = Runtime::new(&Manifest::default_dir())?;
    println!("PJRT platform: {}", rt.platform());

    // 2. Load the batched 1024-point FFT artifact (compiled once, cached).
    let module = rt.load("fft_f32_n1024_b64")?;
    let (batch, n) = (module.meta.batch as usize, module.meta.n as usize);
    println!("artifact: {} ({batch} x {n})", module.meta.name);

    // 3. Run it on random complex data.
    let mut rng = Rng::new(2024);
    let re: Vec<f32> = (0..batch * n).map(|_| rng.gauss() as f32).collect();
    let im: Vec<f32> = (0..batch * n).map(|_| rng.gauss() as f32).collect();
    let out = module.run_f32(&[&re, &im])?;

    // 4. Validate against the pure-rust Stockham oracle.
    let x: Vec<dsp::C64> = (0..n)
        .map(|i| dsp::C64::new(re[i] as f64, im[i] as f64))
        .collect();
    let want = dsp::fft(&x);
    let max_err = (0..n)
        .map(|i| {
            (out[0][i] as f64 - want[i].re)
                .abs()
                .max((out[1][i] as f64 - want[i].im).abs())
        })
        .fold(0.0, f64::max);
    println!("max abs error vs oracle: {max_err:.2e}");
    assert!(max_err < 1e-2);

    // 5. What would this workload cost on a V100, and what does the paper's
    //    mean-optimal clock save?
    let gpu = tesla_v100();
    let w = FftWorkload::new(n as u64, Precision::Fp32, gpu.working_set_bytes);
    let boost = run_batch(&gpu, &w, gpu.boost_clock_mhz);
    let tuned = run_batch(&gpu, &w, 945.0);
    println!(
        "simulated V100, 2 GiB of N={n} FFTs per batch:\n  boost {:.0} MHz: {:.2} J/batch, {:.2} ms\n  tuned  945 MHz: {:.2} J/batch, {:.2} ms\n  energy saving {:.0}% for a {:+.1}% time change",
        gpu.boost_clock_mhz,
        boost.energy_j,
        boost.timing.total_s * 1e3,
        tuned.energy_j,
        tuned.timing.total_s * 1e3,
        (1.0 - tuned.energy_j / boost.energy_j) * 100.0,
        (tuned.timing.total_s / boost.timing.total_s - 1.0) * 100.0,
    );
    println!("quickstart OK");
    Ok(())
}
