"""Unit tests for the bench-smoke CI gate (scripts/check_bench.py).

Run with `python3 -m pytest -q scripts/test_check_bench.py` (a dedicated
CI step): the gate that guards the perf trajectory must itself be tested,
or a refactor could silently turn it into a yes-machine.
"""

import copy
import json

import pytest

import check_bench


def good_doc():
    return {
        "bench": "serving",
        "schema": 3,
        "quick": False,
        "n": 1024,
        "naive_rows_per_s": 12000.0,
        "planned_rows_per_s": 90000.0,
        "planned_speedup": 7.5,
        "nonpow2": {"n": 1536, "rows_per_s": 25000.0},
        "bluestein": {"n": 1009, "rows_per_s": 4000.0},
        "rfft": {"n": 4096, "rows_per_s": 12000.0, "vs_complex": 1.2},
        "native": {
            "f32_rows_per_s": 90000.0,
            "f64_convert_rows_per_s": 40000.0,
            "f32_vs_f64_convert": 2.25,
            "f32_f64_plane_bytes": 0,
            "pool_batches_per_s": 400.0,
            "spawn_batches_per_s": 250.0,
            "pool_vs_spawn": 1.6,
            "pool_workers": 4,
            "pool_threads_spawned": 4,
        },
        "fleet": {
            "jobs_per_s": 1000.0,
            "p50_ms": 3.0,
            "p99_ms": 10.0,
            "allocs_per_job": 12.0,
        },
        "power": {
            "jobs": 1024,
            "budget_w": 350.0,
            "uncapped_draw_1s_w": 500.0,
            "capped_draw_1s_w": 340.0,
            "uncapped_energy_per_job_j": 1.0e-3,
            "capped_energy_per_job_j": 8.0e-4,
            "uncapped_p99_sim_ms": 0.05,
            "capped_p99_sim_ms": 0.1,
            "capped_clock_transitions": 4,
        },
        "large_n": {
            "n": 262144,
            "four_step_rows_per_s": 40.0,
            "monolithic_rows_per_s": 35.0,
            "four_step_vs_monolithic": 1.14,
            "four_step_passes": 7,
            "monolithic_passes": 6,
            "four_step_twiddle_bytes": 30768,
            "monolithic_twiddle_bytes": 6291432,
            "conv_n": 4096,
            "conv_taps": 129,
            "conv_jobs_per_s": 200.0,
            "conv_block_len": 2048,
            "conv_passes_per_block": 9,
        },
        "robustness": {
            "jobs": 1536,
            "faulted_jobs": 3072,
            "fault_free_jobs_per_s": 900.0,
            "faulted_goodput_jobs_per_s": 450.0,
            "goodput_frac": 0.5,
            "jobs_lost": 0,
            "shed_rate": 0.0,
            "jobs_retried": 50,
            "quarantines": 1,
            "fault_free_p99_sim_ms": 0.1,
            "faulted_p99_sim_ms": 0.2,
        },
        "observability": {
            "jobs": 2048,
            "untraced_jobs_per_s": 900.0,
            "traced_jobs_per_s": 880.0,
            "trace_overhead_frac": 0.022,
            "hist_readout_us": 50.0,
            "spans_recorded": 2176,
        },
        "overload": {
            "jobs_per_leg": 1024,
            "arrival": "burst,size=32",
            "capacity_jobs_per_s": 1000.0,
            "goodput_1x_jobs_per_s": 400.0,
            "goodput_4x_jobs_per_s": 400.0,
            "realtime_goodput_4x_jobs_per_s": 400.0,
            "realtime_p99_ms_4x": 250.0,
            "shed_rate_4x": 0.7,
            "untyped_drops": 0,
        },
    }


def write(tmp_path, name, doc):
    p = tmp_path / name
    p.write_text(json.dumps(doc) if isinstance(doc, dict) else doc)
    return str(p)


def test_identical_docs_pass(tmp_path):
    fresh = write(tmp_path, "fresh.json", good_doc())
    base = write(tmp_path, "base.json", good_doc())
    assert check_bench.run(fresh, base, out=lambda _: None) == []


def test_small_regression_within_budget_passes():
    fresh = good_doc()
    fresh["fleet"]["jobs_per_s"] = 800.0  # -20% > floor of -30%
    fresh["fleet"]["p99_ms"] = 12.0  # +20% < ceiling of +30%
    problems, _ = check_bench.check(fresh, good_doc())
    assert problems == []


def test_throughput_regression_fails():
    fresh = good_doc()
    fresh["fleet"]["jobs_per_s"] = 600.0  # -40%
    problems, _ = check_bench.check(fresh, good_doc())
    assert any("throughput" in p for p in problems)


def test_p99_regression_fails():
    fresh = good_doc()
    fresh["fleet"]["p99_ms"] = 14.0  # +40%
    problems, _ = check_bench.check(fresh, good_doc())
    assert any("p99" in p for p in problems)


def test_planned_slower_than_naive_fails():
    fresh = good_doc()
    fresh["planned_speedup"] = 0.9
    problems, _ = check_bench.check(fresh, good_doc())
    assert any("planner regression" in p for p in problems)


def test_nonpositive_offgrid_rate_fails():
    fresh = good_doc()
    fresh["rfft"]["rows_per_s"] = 0.0
    problems, _ = check_bench.check(fresh, good_doc())
    assert any("rfft.rows_per_s" in p for p in problems)


@pytest.mark.parametrize("section", ["nonpow2", "bluestein", "rfft"])
def test_per_shape_rate_floor_is_enforced(section):
    # The baseline's contract: per-shape rows/s are FLOORS, not presence
    # checks — a 40% regression on any opened workload path must fail.
    fresh = good_doc()
    fresh[section]["rows_per_s"] = good_doc()[section]["rows_per_s"] * 0.6
    problems, _ = check_bench.check(fresh, good_doc())
    assert any(f"{section}.rows_per_s" in p and "regressed" in p for p in problems)
    # ...while a 20% dip stays within budget.
    fresh[section]["rows_per_s"] = good_doc()[section]["rows_per_s"] * 0.8
    problems, _ = check_bench.check(fresh, good_doc())
    assert problems == []


def test_planned_rows_floor_is_enforced():
    fresh = good_doc()
    fresh["planned_rows_per_s"] = good_doc()["planned_rows_per_s"] * 0.5
    problems, _ = check_bench.check(fresh, good_doc())
    assert any("planned_rows_per_s" in p for p in problems)


def test_capped_draw_over_budget_fails():
    # Internal invariant of the fresh doc: a capped run whose rolling 1s
    # draw exceeds the budget means enforcement is broken, regardless of
    # what the baseline says.
    fresh = good_doc()
    fresh["power"]["capped_draw_1s_w"] = fresh["power"]["budget_w"] * 1.1
    problems, _ = check_bench.check(fresh, good_doc())
    assert any("not enforced" in p for p in problems)


def test_capping_that_costs_energy_fails():
    fresh = good_doc()
    fresh["power"]["capped_energy_per_job_j"] = (
        fresh["power"]["uncapped_energy_per_job_j"] * 1.2
    )
    problems, _ = check_bench.check(fresh, good_doc())
    assert any("must save energy" in p for p in problems)


@pytest.mark.parametrize("key", ["capped_energy_per_job_j", "capped_p99_sim_ms"])
def test_power_ceilings_vs_baseline_enforced(key):
    # Trajectory gates: capped energy/job and simulated p99 are ceilings
    # relative to the committed baseline.
    fresh = good_doc()
    fresh["power"][key] = good_doc()["power"][key] * 1.5
    if key == "capped_energy_per_job_j":
        # keep the internal capped<=uncapped invariant satisfied so only
        # the baseline ceiling trips
        fresh["power"]["uncapped_energy_per_job_j"] = fresh["power"][key] * 2
    problems, _ = check_bench.check(fresh, good_doc())
    assert any(f"power.{key}" in p for p in problems)
    # a 20% rise stays inside the 30% ceiling
    fresh = good_doc()
    fresh["power"][key] = good_doc()["power"][key] * 1.2
    if key == "capped_energy_per_job_j":
        fresh["power"]["uncapped_energy_per_job_j"] = fresh["power"][key] * 2
    problems, _ = check_bench.check(fresh, good_doc())
    assert problems == []


def test_f32_plane_bytes_nonzero_fails():
    # Internal invariant of the fresh doc: the f32 serving path must not
    # have allocated f64 planes, whatever the baseline says.
    fresh = good_doc()
    fresh["native"]["f32_f64_plane_bytes"] = 8192
    problems, _ = check_bench.check(fresh, good_doc())
    assert any("no-conversion contract" in p for p in problems)


def test_f32_slower_than_f64_convert_fails():
    fresh = good_doc()
    fresh["native"]["f32_rows_per_s"] = fresh["native"]["f64_convert_rows_per_s"] * 0.8
    problems, _ = check_bench.check(fresh, good_doc())
    assert any("must not lose to up-conversion" in p for p in problems)


def test_pool_slower_than_spawn_fails():
    fresh = good_doc()
    fresh["native"]["pool_batches_per_s"] = fresh["native"]["spawn_batches_per_s"] * 0.8
    problems, _ = check_bench.check(fresh, good_doc())
    assert any("must not lose to per-call spawns" in p for p in problems)


@pytest.mark.parametrize("key", ["f32_rows_per_s", "pool_batches_per_s"])
def test_native_floors_vs_baseline_enforced(key):
    # Trajectory gates: f32-native rows/s and pool batches/s are floors
    # relative to the committed baseline — and a fresh value 40% under
    # also trips the internal f32>=f64c / pool>=spawn invariants, so keep
    # those legs proportional and only break the floor.
    fresh = good_doc()
    fresh["native"][key] = good_doc()["native"][key] * 0.6
    if key == "f32_rows_per_s":
        fresh["native"]["f64_convert_rows_per_s"] = fresh["native"][key] * 0.5
    else:
        fresh["native"]["spawn_batches_per_s"] = fresh["native"][key] * 0.5
    problems, _ = check_bench.check(fresh, good_doc())
    assert any(f"native.{key}" in p and "regressed" in p for p in problems)
    # a 20% dip stays within the 30% budget
    fresh = good_doc()
    fresh["native"][key] = good_doc()["native"][key] * 0.8
    if key == "f32_rows_per_s":
        fresh["native"]["f64_convert_rows_per_s"] = fresh["native"][key] * 0.5
    else:
        fresh["native"]["spawn_batches_per_s"] = fresh["native"][key] * 0.5
    problems, _ = check_bench.check(fresh, good_doc())
    assert problems == []


def test_four_step_losing_to_monolithic_fails():
    # Internal invariant of the fresh doc: the four-step decomposition at
    # n=2^18 must hold parity with the monolithic plan (10% slack),
    # whatever the baseline says.
    fresh = good_doc()
    fresh["large_n"]["monolithic_rows_per_s"] = 60.0  # four-step 40 << 54 floor
    problems, _ = check_bench.check(fresh, good_doc())
    assert any("must not lose to the monolithic plan" in p for p in problems)
    # ...parity within the slack passes.
    fresh["large_n"]["monolithic_rows_per_s"] = 42.0
    problems, _ = check_bench.check(fresh, good_doc())
    assert problems == []


def test_four_step_twiddle_table_must_be_smaller():
    fresh = good_doc()
    fresh["large_n"]["four_step_twiddle_bytes"] = fresh["large_n"][
        "monolithic_twiddle_bytes"
    ]
    problems, _ = check_bench.check(fresh, good_doc())
    assert any("split hi/lo factorization" in p for p in problems)


def test_four_step_pass_count_shape_is_pinned():
    # col + row + twiddle sweep = monolithic + 1, exactly — more means the
    # decomposition recursed or grew a pass, fewer means it skipped one.
    fresh = good_doc()
    fresh["large_n"]["four_step_passes"] = fresh["large_n"]["monolithic_passes"] + 2
    problems, _ = check_bench.check(fresh, good_doc())
    assert any("schedule changed shape" in p for p in problems)


@pytest.mark.parametrize("key", ["four_step_rows_per_s", "conv_jobs_per_s"])
def test_large_n_floors_vs_baseline_enforced(key):
    # Trajectory gates: four-step rows/s and conv jobs/s are floors
    # relative to the committed baseline — keep the internal
    # four-step>=monolithic invariant satisfied so only the floor trips.
    fresh = good_doc()
    fresh["large_n"][key] = good_doc()["large_n"][key] * 0.6
    if key == "four_step_rows_per_s":
        fresh["large_n"]["monolithic_rows_per_s"] = fresh["large_n"][key] * 0.5
    problems, _ = check_bench.check(fresh, good_doc())
    assert any(f"large_n.{key}" in p and "regressed" in p for p in problems)
    # a 20% dip stays within the 30% budget
    fresh = good_doc()
    fresh["large_n"][key] = good_doc()["large_n"][key] * 0.8
    if key == "four_step_rows_per_s":
        fresh["large_n"]["monolithic_rows_per_s"] = fresh["large_n"][key] * 0.5
    problems, _ = check_bench.check(fresh, good_doc())
    assert problems == []


def test_lost_jobs_fail_regardless_of_baseline():
    # The fault-tolerance contract is absolute: one lost accepted job
    # fails the gate even if the baseline somehow recorded losses too.
    fresh = good_doc()
    fresh["robustness"]["jobs_lost"] = 1
    problems, _ = check_bench.check(fresh, good_doc())
    assert any("lost under the injected fault" in p for p in problems)


def test_missing_quarantine_fails():
    # Internal invariant of the fresh doc: the fail-stopped card must
    # have been quarantined by the health plane.
    fresh = good_doc()
    fresh["robustness"]["quarantines"] = 0
    problems, _ = check_bench.check(fresh, good_doc())
    assert any("never quarantined" in p for p in problems)


def test_faulted_goodput_floor_is_enforced():
    fresh = good_doc()
    fresh["robustness"]["faulted_goodput_jobs_per_s"] = (
        good_doc()["robustness"]["faulted_goodput_jobs_per_s"] * 0.6
    )
    problems, _ = check_bench.check(fresh, good_doc())
    assert any("robustness.faulted_goodput_jobs_per_s" in p for p in problems)
    # a 20% dip stays within the 30% budget
    fresh["robustness"]["faulted_goodput_jobs_per_s"] = (
        good_doc()["robustness"]["faulted_goodput_jobs_per_s"] * 0.8
    )
    problems, _ = check_bench.check(fresh, good_doc())
    assert problems == []


def test_shed_rate_ceiling_is_enforced():
    # Shed rate is a ceiling: baseline + the small absolute allowance.
    fresh = good_doc()
    fresh["robustness"]["shed_rate"] = (
        good_doc()["robustness"]["shed_rate"] + check_bench.SHED_SLACK + 0.01
    )
    problems, _ = check_bench.check(fresh, good_doc())
    assert any("shedding too much load" in p for p in problems)
    # ... within the allowance passes.
    fresh["robustness"]["shed_rate"] = (
        good_doc()["robustness"]["shed_rate"] + check_bench.SHED_SLACK / 2
    )
    problems, _ = check_bench.check(fresh, good_doc())
    assert problems == []


def test_trace_overhead_budget_is_enforced():
    # Internal invariant of the fresh doc: the traced serve must stay
    # within TRACE_SLACK of the untraced serve, whatever the baseline
    # says — per-job tracing blowing its budget is a regression even if
    # absolute throughput is fine.
    fresh = good_doc()
    fresh["observability"]["traced_jobs_per_s"] = (
        fresh["observability"]["untraced_jobs_per_s"]
        * (1.0 - check_bench.TRACE_SLACK)
        * 0.9
    )
    problems, _ = check_bench.check(fresh, good_doc())
    assert any("blew its overhead budget" in p for p in problems)
    # ... overhead within the budget passes (traced floor also cleared).
    fresh["observability"]["traced_jobs_per_s"] = (
        fresh["observability"]["untraced_jobs_per_s"] * 0.97
    )
    problems, _ = check_bench.check(fresh, good_doc())
    assert problems == []


def test_traced_throughput_floor_is_enforced():
    # Trajectory gate: traced jobs/s is a floor vs the committed baseline
    # — scale both legs down together so the overhead invariant holds and
    # only the floor trips.
    fresh = good_doc()
    fresh["observability"]["untraced_jobs_per_s"] *= 0.6
    fresh["observability"]["traced_jobs_per_s"] *= 0.6
    problems, _ = check_bench.check(fresh, good_doc())
    assert any("observability.traced_jobs_per_s" in p for p in problems)
    # a 20% dip on both legs stays within the 30% budget
    fresh = good_doc()
    fresh["observability"]["untraced_jobs_per_s"] *= 0.8
    fresh["observability"]["traced_jobs_per_s"] *= 0.8
    problems, _ = check_bench.check(fresh, good_doc())
    assert problems == []


def test_untyped_drops_fail_regardless_of_baseline():
    # The overload contract is absolute: every refused job must be a
    # typed shed, even if the baseline somehow recorded untyped drops.
    fresh = good_doc()
    fresh["overload"]["untyped_drops"] = 3
    problems, _ = check_bench.check(fresh, good_doc())
    assert any("not typed sheds" in p for p in problems)


def test_realtime_goodput_collapse_under_overload_fails():
    # Internal invariant of the fresh doc: realtime goodput at 4x must
    # hold 95% of the 1x-load throughput, whatever the baseline says.
    fresh = good_doc()
    fresh["overload"]["realtime_goodput_4x_jobs_per_s"] = (
        fresh["overload"]["goodput_1x_jobs_per_s"]
        * check_bench.REALTIME_GOODPUT_FRAC
        * 0.8
    )
    problems, _ = check_bench.check(fresh, good_doc())
    assert any("stopped protecting the realtime class" in p for p in problems)
    # ... holding exactly the fraction passes (floors vs baseline still
    # cleared because only the realtime leg moved within budget).
    fresh["overload"]["realtime_goodput_4x_jobs_per_s"] = (
        fresh["overload"]["goodput_1x_jobs_per_s"] * check_bench.REALTIME_GOODPUT_FRAC
    )
    problems, _ = check_bench.check(fresh, good_doc())
    assert problems == []


def test_overload_shed_rate_band_is_enforced():
    # Too little shedding at 4x means admission control never bit ...
    fresh = good_doc()
    fresh["overload"]["shed_rate_4x"] = check_bench.OVERLOAD_SHED_MIN * 0.5
    problems, _ = check_bench.check(fresh, good_doc())
    assert any("never triggered admission control" in p for p in problems)
    # ... too much means the fleet collapsed into shedding everything ...
    fresh["overload"]["shed_rate_4x"] = (check_bench.OVERLOAD_SHED_MAX + 1.0) / 2
    problems, _ = check_bench.check(fresh, good_doc())
    assert any("collapsed into shedding" in p for p in problems)
    # ... and anywhere inside the band passes.
    fresh["overload"]["shed_rate_4x"] = (
        check_bench.OVERLOAD_SHED_MIN + check_bench.OVERLOAD_SHED_MAX
    ) / 2
    problems, _ = check_bench.check(fresh, good_doc())
    assert problems == []


@pytest.mark.parametrize("key", ["goodput_1x_jobs_per_s", "goodput_4x_jobs_per_s"])
def test_overload_goodput_floors_vs_baseline_enforced(key):
    # Trajectory gates: 1x and 4x goodput are floors vs the committed
    # baseline — scale the realtime leg with the 1x leg so the internal
    # 95%-of-1x invariant holds and only the floor trips.
    fresh = good_doc()
    fresh["overload"][key] = good_doc()["overload"][key] * 0.6
    if key == "goodput_1x_jobs_per_s":
        fresh["overload"]["realtime_goodput_4x_jobs_per_s"] = fresh["overload"][key]
    problems, _ = check_bench.check(fresh, good_doc())
    assert any(f"overload.{key}" in p and "regressed" in p for p in problems)
    # a 20% dip stays within the 30% budget
    fresh = good_doc()
    fresh["overload"][key] = good_doc()["overload"][key] * 0.8
    if key == "goodput_1x_jobs_per_s":
        fresh["overload"]["realtime_goodput_4x_jobs_per_s"] = fresh["overload"][key]
    problems, _ = check_bench.check(fresh, good_doc())
    assert problems == []


def test_realtime_p99_ceiling_vs_baseline_enforced():
    fresh = good_doc()
    fresh["overload"]["realtime_p99_ms_4x"] = (
        good_doc()["overload"]["realtime_p99_ms_4x"] * 1.5
    )
    problems, _ = check_bench.check(fresh, good_doc())
    assert any("overload.realtime_p99_ms_4x" in p for p in problems)
    # a 20% rise stays inside the 30% ceiling
    fresh["overload"]["realtime_p99_ms_4x"] = (
        good_doc()["overload"]["realtime_p99_ms_4x"] * 1.2
    )
    problems, _ = check_bench.check(fresh, good_doc())
    assert problems == []


def test_overload_without_required_key_is_rejected(tmp_path):
    doc = good_doc()
    del doc["overload"]["untyped_drops"]
    path = write(tmp_path, "fresh.json", doc)
    with pytest.raises(check_bench.BenchCheckError, match="overload.untyped_drops"):
        check_bench.load_doc(path)


def test_overload_as_non_object_is_rejected(tmp_path):
    doc = good_doc()
    doc["overload"] = "sheddy"
    path = write(tmp_path, "fresh.json", doc)
    with pytest.raises(check_bench.BenchCheckError, match="overload.shed_rate_4x"):
        check_bench.load_doc(path)


def test_observability_without_required_key_is_rejected(tmp_path):
    doc = good_doc()
    del doc["observability"]["trace_overhead_frac"]
    path = write(tmp_path, "fresh.json", doc)
    with pytest.raises(
        check_bench.BenchCheckError, match="observability.trace_overhead_frac"
    ):
        check_bench.load_doc(path)


def test_observability_as_non_object_is_rejected(tmp_path):
    doc = good_doc()
    doc["observability"] = "cheap"
    path = write(tmp_path, "fresh.json", doc)
    with pytest.raises(
        check_bench.BenchCheckError, match="observability.traced_jobs_per_s"
    ):
        check_bench.load_doc(path)


def test_robustness_without_required_key_is_rejected(tmp_path):
    doc = good_doc()
    del doc["robustness"]["jobs_lost"]
    path = write(tmp_path, "fresh.json", doc)
    with pytest.raises(check_bench.BenchCheckError, match="robustness.jobs_lost"):
        check_bench.load_doc(path)


def test_robustness_as_non_object_is_rejected(tmp_path):
    doc = good_doc()
    doc["robustness"] = "fine"
    path = write(tmp_path, "fresh.json", doc)
    with pytest.raises(check_bench.BenchCheckError, match="robustness.shed_rate"):
        check_bench.load_doc(path)


def test_large_n_without_required_key_is_rejected(tmp_path):
    doc = good_doc()
    del doc["large_n"]["four_step_rows_per_s"]
    path = write(tmp_path, "fresh.json", doc)
    with pytest.raises(
        check_bench.BenchCheckError, match="large_n.four_step_rows_per_s"
    ):
        check_bench.load_doc(path)


def test_large_n_as_non_object_is_rejected(tmp_path):
    doc = good_doc()
    doc["large_n"] = "fast"
    path = write(tmp_path, "fresh.json", doc)
    with pytest.raises(check_bench.BenchCheckError, match="large_n.conv_jobs_per_s"):
        check_bench.load_doc(path)


def test_native_without_required_key_is_rejected(tmp_path):
    doc = good_doc()
    del doc["native"]["f32_f64_plane_bytes"]
    path = write(tmp_path, "fresh.json", doc)
    with pytest.raises(check_bench.BenchCheckError, match="native.f32_f64_plane_bytes"):
        check_bench.load_doc(path)


def test_native_as_non_object_is_rejected(tmp_path):
    doc = good_doc()
    doc["native"] = 1.0
    path = write(tmp_path, "fresh.json", doc)
    with pytest.raises(check_bench.BenchCheckError, match="native.f32_rows_per_s"):
        check_bench.load_doc(path)


def test_power_without_required_key_is_rejected(tmp_path):
    doc = good_doc()
    del doc["power"]["capped_draw_1s_w"]
    path = write(tmp_path, "fresh.json", doc)
    with pytest.raises(check_bench.BenchCheckError, match="power.capped_draw_1s_w"):
        check_bench.load_doc(path)


def test_power_as_non_object_is_rejected(tmp_path):
    doc = good_doc()
    doc["power"] = 42
    path = write(tmp_path, "fresh.json", doc)
    with pytest.raises(check_bench.BenchCheckError, match="power.budget_w"):
        check_bench.load_doc(path)


@pytest.mark.parametrize(
    "key",
    [
        "fleet",
        "nonpow2",
        "rfft",
        "planned_speedup",
        "power",
        "native",
        "large_n",
        "robustness",
        "observability",
        "overload",
    ],
)
def test_missing_top_level_key_is_rejected(tmp_path, key):
    doc = good_doc()
    del doc[key]
    path = write(tmp_path, "fresh.json", doc)
    with pytest.raises(check_bench.BenchCheckError, match="missing|fleet"):
        check_bench.load_doc(path)


@pytest.mark.parametrize("key", ["jobs_per_s", "p99_ms"])
def test_missing_fleet_key_is_rejected(tmp_path, key):
    doc = good_doc()
    del doc["fleet"][key]
    path = write(tmp_path, "fresh.json", doc)
    with pytest.raises(check_bench.BenchCheckError, match=f"fleet.{key}"):
        check_bench.load_doc(path)


def test_nonpow2_without_rate_is_rejected(tmp_path):
    doc = good_doc()
    doc["nonpow2"] = {"n": 1536}
    path = write(tmp_path, "fresh.json", doc)
    with pytest.raises(check_bench.BenchCheckError, match="nonpow2.rows_per_s"):
        check_bench.load_doc(path)


def test_malformed_json_is_rejected(tmp_path):
    path = write(tmp_path, "fresh.json", "{not json")
    with pytest.raises(check_bench.BenchCheckError, match="malformed"):
        check_bench.load_doc(path)


def test_missing_file_is_rejected(tmp_path):
    with pytest.raises(check_bench.BenchCheckError, match="unreadable"):
        check_bench.load_doc(str(tmp_path / "nope.json"))


def test_non_object_document_is_rejected(tmp_path):
    path = write(tmp_path, "fresh.json", "[1, 2, 3]")
    with pytest.raises(check_bench.BenchCheckError, match="fleet"):
        check_bench.load_doc(path)


def test_run_reports_file_problems_instead_of_raising(tmp_path):
    fresh = write(tmp_path, "fresh.json", good_doc())
    problems = check_bench.run(fresh, str(tmp_path / "missing.json"), out=lambda _: None)
    assert len(problems) == 1 and "unreadable" in problems[0]


def test_main_exits_nonzero_on_regression(tmp_path, capsys):
    fresh_doc = good_doc()
    fresh_doc["fleet"]["jobs_per_s"] = 1.0
    fresh = write(tmp_path, "fresh.json", fresh_doc)
    base = write(tmp_path, "base.json", good_doc())
    with pytest.raises(SystemExit) as e:
        check_bench.main(["check_bench.py", fresh, base])
    assert e.value.code == 1
    assert "FAIL" in capsys.readouterr().out


def test_main_passes_on_good_docs(tmp_path, capsys):
    fresh = write(tmp_path, "fresh.json", good_doc())
    base = write(tmp_path, "base.json", good_doc())
    check_bench.main(["check_bench.py", fresh, base])
    assert "OK" in capsys.readouterr().out


def test_committed_baseline_is_itself_valid():
    # The repo-root baseline must always satisfy the structural gate —
    # otherwise every CI run fails at the load step.
    import os

    here = os.path.dirname(os.path.abspath(__file__))
    baseline = os.path.join(here, "..", "BENCH_serving.json")
    doc = check_bench.load_doc(baseline)
    problems, _ = check_bench.check(copy.deepcopy(doc), doc)
    assert problems == []
