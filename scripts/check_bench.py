#!/usr/bin/env python3
"""CI gate for the serving-bench trajectory (bench-smoke job).

Usage: check_bench.py <fresh BENCH_serving.json> <committed baseline>

Fails (exit 1) when:
  * either file is malformed JSON or missing required fields (including
    the non-pow2 / rFFT rows the plan compiler emits and the telemetry
    `power` section),
  * fleet throughput regressed more than 30% below the committed baseline,
  * closed-loop p99 latency regressed more than 30% above the baseline,
  * the planned path is slower than the naive per-row path,
  * planned rows/s or any opened-workload row (nonpow2/bluestein/rfft)
    regressed more than 30% below its baseline rate (or is non-positive),
  * the power section breaks an internal invariant of the fresh doc —
    capped 1s draw above the budget, or capped energy/job above the
    uncapped run's (the cap must actually cap, and must save energy) —
    or capped energy/job / capped simulated p99 rose more than 30% above
    the committed baseline ceilings,
  * the native section (schema 4) breaks an internal invariant of the
    fresh doc — the f32 serving path allocated f64 planes
    (f32_f64_plane_bytes != 0), f32-native rows/s fell below the
    f64-convert rate, or the persistent pool fell below the scoped-spawn
    rate — or f32-native rows/s / pool batches/s regressed more than 30%
    below their committed baseline floors,
  * the large_n section (schema 5) breaks an internal invariant of the
    fresh doc — the four-step path at n=2^18 fell below the monolithic
    plan's rows/s, its twiddle-table bytes are not strictly smaller than
    the monolithic table, or its pass count is not exactly monolithic + 1
    (the decomposition trades one extra pass for L2-resident sub-plans
    and a split twiddle table) — or four-step rows/s / conv jobs/s
    regressed more than 30% below their committed baseline floors,
  * the robustness section (schema 6) breaks an internal invariant of
    the fresh doc — any accepted job was lost under the injected fault
    (jobs_lost != 0: the fault-tolerance contract is every submit
    resolves to a result or a typed error), or the fail-stopped card was
    never quarantined — or the faulted-fleet goodput regressed more than
    30% below the committed baseline floor, or the shed rate rose above
    the baseline plus a small absolute allowance,
  * the observability section (schema 7) breaks its internal invariant —
    the traced serve fell more than 5% below the untraced serve of the
    identical workload (request tracing blew its overhead budget) — or
    traced throughput regressed more than 30% below the committed
    baseline floor,
  * the overload section (schema 8) breaks an internal invariant of the
    fresh doc — a refused job was not a typed shed (untyped_drops != 0),
    realtime-class goodput at 4x offered load fell below 0.95x the
    1x-load throughput (QoS stopped protecting the realtime class), or
    the 4x shed rate left the [0.2, 0.95] band (admission control either
    never bit, or the fleet collapsed into shedding everything) — or
    goodput at 1x/4x regressed more than 30% below its committed
    baseline floor, or realtime p99 at 4x rose more than 30% above the
    baseline ceiling.

The committed baseline is intentionally conservative: throughputs are the
floor the trajectory must never fall under and p99 the ceiling it must
never rise over — not the best numbers ever seen. Update it (from a
`cargo bench --bench bench_serving` run on a quiet machine) when a PR
intentionally moves serving performance.

The checking logic lives in pure functions (`load_doc`, `check`) so
`test_check_bench.py` can unit-test pass/regress/malformed cases without
spawning processes.
"""

import json
import sys

REQUIRED = [
    "bench",
    "schema",
    "naive_rows_per_s",
    "planned_rows_per_s",
    "planned_speedup",
    "nonpow2",
    "rfft",
    "fleet",
    "power",
    "native",
    "large_n",
    "robustness",
    "observability",
    "overload",
]
REQUIRED_FLEET = ["jobs_per_s", "p50_ms", "p99_ms", "allocs_per_job"]
REQUIRED_RATE = ["rows_per_s"]  # for the nonpow2/bluestein/rfft objects
REQUIRED_POWER = [
    "budget_w",
    "uncapped_draw_1s_w",
    "capped_draw_1s_w",
    "uncapped_energy_per_job_j",
    "capped_energy_per_job_j",
    "capped_p99_sim_ms",
]
REQUIRED_NATIVE = [
    "f32_rows_per_s",
    "f64_convert_rows_per_s",
    "f32_f64_plane_bytes",
    "pool_batches_per_s",
    "spawn_batches_per_s",
]
REQUIRED_LARGE_N = [
    "four_step_rows_per_s",
    "monolithic_rows_per_s",
    "four_step_passes",
    "monolithic_passes",
    "four_step_twiddle_bytes",
    "monolithic_twiddle_bytes",
    "conv_jobs_per_s",
]
REQUIRED_ROBUSTNESS = [
    "fault_free_jobs_per_s",
    "faulted_goodput_jobs_per_s",
    "jobs_lost",
    "shed_rate",
    "quarantines",
]
REQUIRED_OBSERVABILITY = [
    "untraced_jobs_per_s",
    "traced_jobs_per_s",
    "trace_overhead_frac",
    "hist_readout_us",
]
REQUIRED_OVERLOAD = [
    "goodput_1x_jobs_per_s",
    "goodput_4x_jobs_per_s",
    "realtime_goodput_4x_jobs_per_s",
    "realtime_p99_ms_4x",
    "shed_rate_4x",
    "untyped_drops",
]
MAX_REGRESSION = 0.30
# Internal-invariant slack: simulated quantities are deterministic, so the
# capped run only gets rounding headroom, not a regression budget.
POWER_SLACK = 0.02
# Wall-clock comparisons within one fresh doc (f32-native vs f64-convert,
# pool vs spawn) get a little timing-noise headroom — the real deltas are
# 1.5x+, so 10% slack never masks an actual inversion.
NATIVE_SLACK = 0.10
# Four-step vs monolithic at n=2^18: same timing-noise headroom — the
# decomposition must at minimum hold parity with the monolithic plan.
LARGE_N_SLACK = 0.10
# Absolute allowance on the faulted-fleet shed rate above the committed
# baseline: retries make sheds rare, but a shed is a typed, accounted
# outcome, so a tiny scheduling-dependent drift is not a gate failure.
SHED_SLACK = 0.02
# Per-job request tracing (span record + histogram update + ring write)
# must stay inside this fraction of the untraced serve's throughput —
# the observability overhead budget the bench measures directly.
TRACE_SLACK = 0.05
# Overload (schema 8): realtime-class goodput at 4x offered load must
# hold this fraction of the 1x-load throughput — the QoS contract that
# brownout + class-ordered backpressure protect the realtime class.
REALTIME_GOODPUT_FRAC = 0.95
# The 4x shed rate must land in this band: below the floor means 4x
# offered load never triggered admission control (unbounded queue growth
# in disguise); above the ceiling means the fleet collapsed into
# shedding nearly everything instead of serving at capacity.
OVERLOAD_SHED_MIN = 0.2
OVERLOAD_SHED_MAX = 0.95


class BenchCheckError(Exception):
    """A file-level problem (unreadable, malformed, missing fields)."""


def load_doc(path):
    """Load and structurally validate one BENCH_serving.json."""
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, ValueError) as e:
        raise BenchCheckError(f"{path}: unreadable or malformed JSON ({e})")
    if not isinstance(doc, dict) or not isinstance(doc.get("fleet"), dict):
        raise BenchCheckError(f"{path}: expected an object with a 'fleet' object")
    missing = [k for k in REQUIRED if k not in doc]
    missing += [f"fleet.{k}" for k in REQUIRED_FLEET if k not in doc["fleet"]]
    if isinstance(doc.get("power"), dict):
        missing += [f"power.{k}" for k in REQUIRED_POWER if k not in doc["power"]]
    elif "power" in doc:
        missing += [f"power.{k}" for k in REQUIRED_POWER]
    if isinstance(doc.get("native"), dict):
        missing += [f"native.{k}" for k in REQUIRED_NATIVE if k not in doc["native"]]
    elif "native" in doc:
        missing += [f"native.{k}" for k in REQUIRED_NATIVE]
    if isinstance(doc.get("large_n"), dict):
        missing += [f"large_n.{k}" for k in REQUIRED_LARGE_N if k not in doc["large_n"]]
    elif "large_n" in doc:
        missing += [f"large_n.{k}" for k in REQUIRED_LARGE_N]
    if isinstance(doc.get("robustness"), dict):
        missing += [
            f"robustness.{k}" for k in REQUIRED_ROBUSTNESS if k not in doc["robustness"]
        ]
    elif "robustness" in doc:
        missing += [f"robustness.{k}" for k in REQUIRED_ROBUSTNESS]
    if isinstance(doc.get("observability"), dict):
        missing += [
            f"observability.{k}"
            for k in REQUIRED_OBSERVABILITY
            if k not in doc["observability"]
        ]
    elif "observability" in doc:
        missing += [f"observability.{k}" for k in REQUIRED_OBSERVABILITY]
    if isinstance(doc.get("overload"), dict):
        missing += [f"overload.{k}" for k in REQUIRED_OVERLOAD if k not in doc["overload"]]
    elif "overload" in doc:
        missing += [f"overload.{k}" for k in REQUIRED_OVERLOAD]
    for section in ("nonpow2", "rfft", "bluestein"):
        sub = doc.get(section)
        if isinstance(sub, dict):
            missing += [f"{section}.{k}" for k in REQUIRED_RATE if k not in sub]
        elif section in REQUIRED:
            # present-but-not-an-object counts as missing its rate key
            missing += [f"{section}.{k}" for k in REQUIRED_RATE]
    if missing:
        raise BenchCheckError(f"{path}: missing fields {missing}")
    return doc


def check(fresh, base):
    """Compare a fresh doc against the baseline.

    Returns (problems, info): problems is a list of failure strings
    (empty = gate passes), info a list of human-readable summary lines.
    """
    problems = []
    info = []

    got = fresh["fleet"]["jobs_per_s"]
    floor = base["fleet"]["jobs_per_s"] * (1.0 - MAX_REGRESSION)
    info.append(
        f"fleet throughput: {got:.0f} jobs/s "
        f"(baseline {base['fleet']['jobs_per_s']:.0f}, floor {floor:.0f})"
    )
    if got < floor:
        problems.append(
            f"throughput {got:.0f} jobs/s regressed >{MAX_REGRESSION:.0%} "
            f"below baseline floor {floor:.0f}"
        )

    p99 = fresh["fleet"]["p99_ms"]
    ceiling = base["fleet"]["p99_ms"] * (1.0 + MAX_REGRESSION)
    info.append(
        f"closed-loop p99: {p99:.3f} ms "
        f"(baseline {base['fleet']['p99_ms']:.3f}, ceiling {ceiling:.3f})"
    )
    if p99 > ceiling:
        problems.append(
            f"p99 latency {p99:.3f} ms regressed >{MAX_REGRESSION:.0%} "
            f"above baseline ceiling {ceiling:.3f} ms"
        )

    info.append(f"planned speedup vs pre-plan path: {fresh['planned_speedup']:.1f}x")
    if fresh["planned_speedup"] < 1.0:
        problems.append("planned path slower than the naive per-row path — planner regression")

    rate_floor = base["planned_rows_per_s"] * (1.0 - MAX_REGRESSION)
    if fresh["planned_rows_per_s"] < rate_floor:
        problems.append(
            f"planned_rows_per_s {fresh['planned_rows_per_s']:.0f} regressed "
            f">{MAX_REGRESSION:.0%} below baseline floor {rate_floor:.0f}"
        )

    # Per-shape rows/s are floors too (the baseline's own contract): each
    # opened workload path is gated against the committed rate.
    for section in ("nonpow2", "rfft", "bluestein"):
        sub = fresh.get(section)
        if not isinstance(sub, dict):
            continue
        rate = sub.get("rows_per_s", 0)
        info.append(f"{section} (n={sub.get('n', '?')}): {rate:.0f} rows/s")
        if not rate > 0:
            problems.append(f"{section}.rows_per_s is not positive ({rate})")
            continue
        base_sub = base.get(section)
        if isinstance(base_sub, dict) and base_sub.get("rows_per_s", 0) > 0:
            floor = base_sub["rows_per_s"] * (1.0 - MAX_REGRESSION)
            if rate < floor:
                problems.append(
                    f"{section}.rows_per_s {rate:.0f} regressed >{MAX_REGRESSION:.0%} "
                    f"below baseline floor {floor:.0f}"
                )

    # Native section (schema 4): internal invariants of the fresh doc.
    # The f32 serving path must not have touched f64 planes, must beat the
    # f64-convert leg, and the persistent pool must beat per-call spawns.
    native = fresh["native"]
    base_native = base["native"]
    info.append(
        f"native: f32 {native['f32_rows_per_s']:.0f} rows/s vs f64-convert "
        f"{native['f64_convert_rows_per_s']:.0f} rows/s, pool "
        f"{native['pool_batches_per_s']:.0f} vs spawn "
        f"{native['spawn_batches_per_s']:.0f} batches/s, f64 plane bytes "
        f"{native['f32_f64_plane_bytes']}"
    )
    if native["f32_f64_plane_bytes"] != 0:
        problems.append(
            f"native: f32 path allocated {native['f32_f64_plane_bytes']} bytes of "
            "f64 planes — the no-conversion contract is broken"
        )
    if native["f32_rows_per_s"] < native["f64_convert_rows_per_s"] * (1.0 - NATIVE_SLACK):
        problems.append(
            f"native: f32-native {native['f32_rows_per_s']:.0f} rows/s below the "
            f"f64-convert path's {native['f64_convert_rows_per_s']:.0f} — native "
            "precision must not lose to up-conversion"
        )
    if native["pool_batches_per_s"] < native["spawn_batches_per_s"] * (1.0 - NATIVE_SLACK):
        problems.append(
            f"native: pool {native['pool_batches_per_s']:.0f} batches/s below "
            f"scoped-spawn {native['spawn_batches_per_s']:.0f} — the persistent "
            "pool must not lose to per-call spawns"
        )
    # … and trajectory floors vs the committed baseline.
    for key, what in (
        ("f32_rows_per_s", "rows/s"),
        ("pool_batches_per_s", "batches/s"),
    ):
        floor = base_native[key] * (1.0 - MAX_REGRESSION)
        if native[key] < floor:
            problems.append(
                f"native.{key} {native[key]:.0f} {what} regressed "
                f">{MAX_REGRESSION:.0%} below baseline floor {floor:.0f}"
            )

    # Large-N section (schema 5): internal invariants of the fresh doc.
    # The four-step decomposition must hold parity with the monolithic
    # plan at n=2^18, carry a strictly smaller twiddle table (that is the
    # point of the split hi/lo factorization), and cost exactly one extra
    # pass (column FFTs + row FFTs + the inter-step twiddle sweep).
    large = fresh["large_n"]
    base_large = base["large_n"]
    info.append(
        f"large_n (n={large.get('n', '?')}): four-step "
        f"{large['four_step_rows_per_s']:.1f} rows/s "
        f"({large['four_step_passes']} passes, "
        f"{large['four_step_twiddle_bytes']} tw bytes) vs monolithic "
        f"{large['monolithic_rows_per_s']:.1f} rows/s "
        f"({large['monolithic_passes']} passes, "
        f"{large['monolithic_twiddle_bytes']} tw bytes); conv "
        f"{large['conv_jobs_per_s']:.0f} jobs/s"
    )
    if large["four_step_rows_per_s"] < large["monolithic_rows_per_s"] * (
        1.0 - LARGE_N_SLACK
    ):
        problems.append(
            f"large_n: four-step {large['four_step_rows_per_s']:.1f} rows/s below "
            f"monolithic {large['monolithic_rows_per_s']:.1f} — the cache-blocked "
            "decomposition must not lose to the monolithic plan at 2^18"
        )
    if not large["four_step_twiddle_bytes"] < large["monolithic_twiddle_bytes"]:
        problems.append(
            f"large_n: four-step twiddle table {large['four_step_twiddle_bytes']} B "
            f"not smaller than monolithic {large['monolithic_twiddle_bytes']} B — "
            "the split hi/lo factorization is broken"
        )
    if large["four_step_passes"] != large["monolithic_passes"] + 1:
        problems.append(
            f"large_n: four-step pass count {large['four_step_passes']} != "
            f"monolithic {large['monolithic_passes']} + 1 — the decomposition "
            "schedule changed shape"
        )
    # … and trajectory floors vs the committed baseline.
    for key, what in (
        ("four_step_rows_per_s", "rows/s"),
        ("conv_jobs_per_s", "jobs/s"),
    ):
        floor = base_large[key] * (1.0 - MAX_REGRESSION)
        if large[key] < floor:
            problems.append(
                f"large_n.{key} {large[key]:.0f} {what} regressed "
                f">{MAX_REGRESSION:.0%} below baseline floor {floor:.0f}"
            )

    # Robustness section (schema 6): internal invariants of the fresh doc
    # first. Zero lost jobs is the fault-tolerance contract itself —
    # every accepted submit resolves to a result or a typed error, even
    # with a card fail-stopped mid-run — and the fail-stopped card must
    # have been quarantined by the health plane.
    robust = fresh["robustness"]
    base_robust = base["robustness"]
    info.append(
        f"robustness: faulted goodput {robust['faulted_goodput_jobs_per_s']:.0f} jobs/s "
        f"(fault-free {robust['fault_free_jobs_per_s']:.0f}), "
        f"{robust['jobs_lost']} lost, shed rate {robust['shed_rate']:.4f}, "
        f"{robust['quarantines']} quarantine(s)"
    )
    if robust["jobs_lost"] != 0:
        problems.append(
            f"robustness: {robust['jobs_lost']} accepted job(s) lost under the "
            "injected fault — every submit must resolve to a result or a typed error"
        )
    if robust["quarantines"] < 1:
        problems.append(
            "robustness: the fail-stopped card was never quarantined — the health "
            "state machine is not isolating hard failures"
        )
    # … then the trajectory floor/ceiling vs the committed baseline: the
    # degraded-but-alive fleet must keep its goodput, and must not shed a
    # larger fraction of the offered load than the baseline run did.
    floor = base_robust["faulted_goodput_jobs_per_s"] * (1.0 - MAX_REGRESSION)
    if robust["faulted_goodput_jobs_per_s"] < floor:
        problems.append(
            f"robustness.faulted_goodput_jobs_per_s "
            f"{robust['faulted_goodput_jobs_per_s']:.0f} regressed "
            f">{MAX_REGRESSION:.0%} below baseline floor {floor:.0f}"
        )
    shed_ceiling = base_robust["shed_rate"] + SHED_SLACK
    if robust["shed_rate"] > shed_ceiling:
        problems.append(
            f"robustness.shed_rate {robust['shed_rate']:.4f} above the baseline "
            f"ceiling {shed_ceiling:.4f} — the retry path is shedding too much load"
        )

    # Observability section (schema 7): internal invariant of the fresh
    # doc first — request tracing prices every job (span record, histogram
    # update, ring write) and that price must stay inside the 5% budget
    # the tracing-on-by-default decision rests on.
    obs = fresh["observability"]
    base_obs = base["observability"]
    info.append(
        f"observability: traced {obs['traced_jobs_per_s']:.0f} jobs/s vs untraced "
        f"{obs['untraced_jobs_per_s']:.0f} jobs/s "
        f"(overhead {obs['trace_overhead_frac']:.1%}), summary readout "
        f"{obs['hist_readout_us']:.1f} us"
    )
    trace_floor = obs["untraced_jobs_per_s"] * (1.0 - TRACE_SLACK)
    if obs["traced_jobs_per_s"] < trace_floor:
        problems.append(
            f"observability: traced serve {obs['traced_jobs_per_s']:.0f} jobs/s fell "
            f"below {trace_floor:.0f} ({TRACE_SLACK:.0%} under the untraced "
            f"{obs['untraced_jobs_per_s']:.0f}) — request tracing blew its "
            "overhead budget"
        )
    # … then the trajectory floor vs the committed baseline.
    floor = base_obs["traced_jobs_per_s"] * (1.0 - MAX_REGRESSION)
    if obs["traced_jobs_per_s"] < floor:
        problems.append(
            f"observability.traced_jobs_per_s {obs['traced_jobs_per_s']:.0f} "
            f"regressed >{MAX_REGRESSION:.0%} below baseline floor {floor:.0f}"
        )

    # Overload section (schema 8): internal invariants of the fresh doc
    # first. Every refused job must be a typed shed (untyped_drops == 0:
    # the overload contract is a typed error + traced span, never a
    # silent drop), realtime goodput at 4x offered load must hold 95% of
    # the 1x-load throughput (the QoS ladder protects the realtime
    # class), and the 4x shed rate must land in a sane band.
    over = fresh["overload"]
    base_over = base["overload"]
    info.append(
        f"overload: 1x goodput {over['goodput_1x_jobs_per_s']:.0f} jobs/s, 4x goodput "
        f"{over['goodput_4x_jobs_per_s']:.0f} jobs/s (realtime "
        f"{over['realtime_goodput_4x_jobs_per_s']:.0f} jobs/s, p99 "
        f"{over['realtime_p99_ms_4x']:.2f} ms, shed rate {over['shed_rate_4x']:.3f}), "
        f"{over['untyped_drops']} untyped drop(s)"
    )
    if over["untyped_drops"] != 0:
        problems.append(
            f"overload: {over['untyped_drops']} refused job(s) were not typed sheds — "
            "every drop must be a typed error with a traced span"
        )
    rt_floor = over["goodput_1x_jobs_per_s"] * REALTIME_GOODPUT_FRAC
    if over["realtime_goodput_4x_jobs_per_s"] < rt_floor:
        problems.append(
            f"overload: realtime goodput at 4x {over['realtime_goodput_4x_jobs_per_s']:.0f} "
            f"jobs/s below {REALTIME_GOODPUT_FRAC:.0%} of the 1x-load throughput "
            f"({rt_floor:.0f}) — QoS stopped protecting the realtime class"
        )
    if over["shed_rate_4x"] < OVERLOAD_SHED_MIN:
        problems.append(
            f"overload: shed rate at 4x {over['shed_rate_4x']:.3f} below "
            f"{OVERLOAD_SHED_MIN} — 4x offered load never triggered admission control "
            "(unbounded queue growth in disguise)"
        )
    if over["shed_rate_4x"] > OVERLOAD_SHED_MAX:
        problems.append(
            f"overload: shed rate at 4x {over['shed_rate_4x']:.3f} above "
            f"{OVERLOAD_SHED_MAX} — the fleet collapsed into shedding instead of "
            "serving at capacity"
        )
    # … then trajectory floors/ceiling vs the committed baseline.
    for key in ("goodput_1x_jobs_per_s", "goodput_4x_jobs_per_s"):
        floor = base_over[key] * (1.0 - MAX_REGRESSION)
        if over[key] < floor:
            problems.append(
                f"overload.{key} {over[key]:.0f} jobs/s regressed "
                f">{MAX_REGRESSION:.0%} below baseline floor {floor:.0f}"
            )
    ceiling = base_over["realtime_p99_ms_4x"] * (1.0 + MAX_REGRESSION)
    if over["realtime_p99_ms_4x"] > ceiling:
        problems.append(
            f"overload.realtime_p99_ms_4x {over['realtime_p99_ms_4x']:.2f} ms rose "
            f">{MAX_REGRESSION:.0%} above baseline ceiling {ceiling:.2f} ms"
        )

    # Power section: internal invariants of the fresh doc first — the cap
    # must actually cap, and capping must not cost energy per job …
    power = fresh["power"]
    base_power = base["power"]
    info.append(
        f"power: capped {power['capped_draw_1s_w']:.1f} W vs budget "
        f"{power['budget_w']:.1f} W (uncapped {power['uncapped_draw_1s_w']:.1f} W), "
        f"energy/job {power['capped_energy_per_job_j']:.3e} J capped vs "
        f"{power['uncapped_energy_per_job_j']:.3e} J uncapped"
    )
    if power["capped_draw_1s_w"] > power["budget_w"] * (1.0 + POWER_SLACK):
        problems.append(
            f"power: capped 1s draw {power['capped_draw_1s_w']:.1f} W exceeds the "
            f"{power['budget_w']:.1f} W budget — the cap is not enforced"
        )
    if power["capped_energy_per_job_j"] > power["uncapped_energy_per_job_j"] * (
        1.0 + POWER_SLACK
    ):
        problems.append(
            "power: capped energy/job "
            f"{power['capped_energy_per_job_j']:.3e} J above uncapped "
            f"{power['uncapped_energy_per_job_j']:.3e} J — capping must save energy"
        )
    # … then trajectory ceilings vs the committed baseline (simulated
    # quantities, so 30% headroom is generous).
    for key, unit in (("capped_energy_per_job_j", "J"), ("capped_p99_sim_ms", "ms")):
        ceiling = base_power[key] * (1.0 + MAX_REGRESSION)
        if fresh["power"][key] > ceiling:
            problems.append(
                f"power.{key} {fresh['power'][key]:.4g} {unit} rose "
                f">{MAX_REGRESSION:.0%} above baseline ceiling {ceiling:.4g} {unit}"
            )

    return problems, info


def run(fresh_path, base_path, out=print):
    """Full gate over two files; returns the list of problems."""
    try:
        fresh = load_doc(fresh_path)
        base = load_doc(base_path)
    except BenchCheckError as e:
        return [str(e)]
    problems, info = check(fresh, base)
    for line in info:
        out(line)
    return problems


def main(argv):
    if len(argv) != 3:
        sys.exit(f"usage: {argv[0]} <fresh.json> <baseline.json>")
    problems = run(argv[1], argv[2])
    for p in problems:
        print(f"FAIL: {p}")
    if problems:
        sys.exit(1)
    print("OK")


if __name__ == "__main__":
    main(sys.argv)
