#!/usr/bin/env python3
"""CI gate for the serving-bench trajectory (bench-smoke job).

Usage: check_bench.py <fresh BENCH_serving.json> <committed baseline>

Fails (exit 1) when:
  * either file is malformed JSON or missing required fields,
  * fleet throughput regressed more than 30% below the committed baseline.

The committed baseline is intentionally conservative: it is the floor the
trajectory must never fall under, not the best number ever seen. Update it
(from a `cargo bench --bench bench_serving` run on a quiet machine) when a
PR intentionally moves serving performance.
"""

import json
import sys

REQUIRED = ["bench", "schema", "naive_rows_per_s", "planned_rows_per_s", "planned_speedup", "fleet"]
REQUIRED_FLEET = ["jobs_per_s", "p50_ms", "p99_ms", "allocs_per_job"]
MAX_REGRESSION = 0.30


def load(path):
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, ValueError) as e:
        sys.exit(f"FAIL: {path}: unreadable or malformed JSON ({e})")
    if not isinstance(doc, dict) or not isinstance(doc.get("fleet"), dict):
        sys.exit(f"FAIL: {path}: expected an object with a 'fleet' object")
    missing = [k for k in REQUIRED if k not in doc]
    missing += [f"fleet.{k}" for k in REQUIRED_FLEET if k not in doc["fleet"]]
    if missing:
        sys.exit(f"FAIL: {path}: missing fields {missing}")
    return doc


def main():
    if len(sys.argv) != 3:
        sys.exit(f"usage: {sys.argv[0]} <fresh.json> <baseline.json>")
    fresh = load(sys.argv[1])
    base = load(sys.argv[2])

    got = fresh["fleet"]["jobs_per_s"]
    floor = base["fleet"]["jobs_per_s"] * (1.0 - MAX_REGRESSION)
    print(f"fleet throughput: {got:.0f} jobs/s (baseline {base['fleet']['jobs_per_s']:.0f}, floor {floor:.0f})")
    print(f"planned speedup vs pre-plan path: {fresh['planned_speedup']:.1f}x")
    if got < floor:
        sys.exit(f"FAIL: throughput {got:.0f} jobs/s regressed >{MAX_REGRESSION:.0%} below baseline floor {floor:.0f}")
    if fresh["planned_speedup"] < 1.0:
        sys.exit("FAIL: planned path slower than the naive per-row path — planner regression")
    print("OK")


if __name__ == "__main__":
    main()
