"""Unit tests for the trace-smoke CI gate (scripts/check_trace.py).

Run with `python3 -m pytest -q scripts/test_check_trace.py`: the gate
that asserts the serving stack's event journal is complete and well
formed must itself be tested.
"""

import json

import pytest

import check_trace


def good_span(job_id=0, outcome="ok"):
    return {
        "job_id": job_id,
        "artifact": "fft_f32_n1024_b64",
        "n": 1024,
        "card": 0,
        "enqueue_us": 100,
        "admit_us": 105,
        "seal_us": 400,
        "dispatch_us": 410,
        "exec_start_us": 450,
        "exec_end_us": 1450,
        "complete_us": 1460,
        "requested_mhz": 945.0,
        "granted_mhz": 945.0,
        "batch_occupancy": 64,
        "attempts": 1,
        "energy_j": 2.5e-4,
        "sim_batch_s": 8.0e-4,
        "outcome": outcome,
    }


def write_journal(tmp_path, spans, name="trace.jsonl"):
    p = tmp_path / name
    p.write_text("".join(json.dumps(s) + "\n" for s in spans))
    return str(p)


def test_good_journal_passes(tmp_path):
    path = write_journal(tmp_path, [good_span(i) for i in range(8)])
    assert check_trace.run(path, expected_ok=8, out=lambda _: None) == []


def test_expected_count_mismatch_fails(tmp_path):
    path = write_journal(tmp_path, [good_span(i) for i in range(8)])
    problems = check_trace.run(path, expected_ok=10, out=lambda _: None)
    assert any("expected 10" in p for p in problems)


def test_shed_spans_do_not_count_toward_ok(tmp_path):
    spans = [good_span(i) for i in range(4)]
    shed = good_span(99, outcome="shed")
    shed["energy_j"] = 0.0
    shed["batch_occupancy"] = 0
    spans.append(shed)
    path = write_journal(tmp_path, spans)
    assert check_trace.run(path, expected_ok=4, out=lambda _: None) == []


def test_non_monotone_stamps_fail(tmp_path):
    bad = good_span()
    bad["dispatch_us"] = bad["seal_us"] - 50
    path = write_journal(tmp_path, [bad])
    problems = check_trace.run(path, expected_ok=1, out=lambda _: None)
    assert any("not monotone" in p for p in problems)


def test_missing_field_names_the_line(tmp_path):
    bad = good_span(1)
    del bad["energy_j"]
    path = write_journal(tmp_path, [good_span(0), bad])
    problems = check_trace.run(path, out=lambda _: None)
    assert any("line 2" in p and "energy_j" in p for p in problems)


def test_executed_span_without_energy_fails(tmp_path):
    bad = good_span()
    bad["energy_j"] = 0.0
    path = write_journal(tmp_path, [bad])
    problems = check_trace.run(path, out=lambda _: None)
    assert any("non-positive" in p for p in problems)


def test_unknown_outcome_fails(tmp_path):
    bad = good_span()
    bad["outcome"] = "maybe"
    path = write_journal(tmp_path, [bad])
    problems = check_trace.run(path, out=lambda _: None)
    assert any("unknown outcome" in p for p in problems)


def test_malformed_line_is_rejected_with_line_number(tmp_path):
    p = tmp_path / "trace.jsonl"
    p.write_text(json.dumps(good_span()) + "\nnot json\n")
    with pytest.raises(check_trace.TraceCheckError, match=":2"):
        check_trace.load_spans(str(p))


def test_blank_lines_are_skipped(tmp_path):
    p = tmp_path / "trace.jsonl"
    p.write_text(json.dumps(good_span()) + "\n\n" + json.dumps(good_span(1)) + "\n")
    assert len(check_trace.load_spans(str(p))) == 2


def test_empty_journal_fails(tmp_path):
    p = tmp_path / "trace.jsonl"
    p.write_text("\n")
    problems = check_trace.run(str(p), out=lambda _: None)
    assert any("no spans" in p for p in problems)


def test_missing_file_is_reported_not_raised(tmp_path):
    problems = check_trace.run(str(tmp_path / "nope.jsonl"), out=lambda _: None)
    assert len(problems) == 1 and "unreadable" in problems[0]


def test_main_exits_nonzero_on_mismatch(tmp_path, capsys):
    path = write_journal(tmp_path, [good_span(i) for i in range(3)])
    with pytest.raises(SystemExit) as e:
        check_trace.main(["check_trace.py", path, "5"])
    assert e.value.code == 1
    assert "FAIL" in capsys.readouterr().out


def test_main_passes_on_good_journal(tmp_path, capsys):
    path = write_journal(tmp_path, [good_span(i) for i in range(3)])
    check_trace.main(["check_trace.py", path, "3"])
    assert "OK" in capsys.readouterr().out
