"""Unit tests for the trace-smoke CI gate (scripts/check_trace.py).

Run with `python3 -m pytest -q scripts/test_check_trace.py`: the gate
that asserts the serving stack's event journal is complete and well
formed must itself be tested.
"""

import json

import pytest

import check_trace


def good_span(job_id=0, outcome="ok", cls="batch"):
    return {
        "job_id": job_id,
        "artifact": "fft_f32_n1024_b64",
        "n": 1024,
        "card": 0,
        "enqueue_us": 100,
        "admit_us": 105,
        "seal_us": 400,
        "dispatch_us": 410,
        "exec_start_us": 450,
        "exec_end_us": 1450,
        "complete_us": 1460,
        "requested_mhz": 945.0,
        "granted_mhz": 945.0,
        "batch_occupancy": 64,
        "attempts": 1,
        "energy_j": 2.5e-4,
        "sim_batch_s": 8.0e-4,
        "outcome": outcome,
        "class": cls,
        "reason": "",
    }


def shed_span(job_id=99, cls="scavenger", reason="brownout shed"):
    """A well-formed shed: reason present, no exec window, no energy."""
    s = good_span(job_id, outcome="shed", cls=cls)
    s["reason"] = reason
    s["energy_j"] = 0.0
    s["batch_occupancy"] = 0
    s["exec_start_us"] = s["admit_us"]
    s["exec_end_us"] = s["admit_us"]
    s["dispatch_us"] = s["admit_us"]
    s["seal_us"] = s["admit_us"]
    s["complete_us"] = s["admit_us"]
    return s


def write_journal(tmp_path, spans, name="trace.jsonl"):
    p = tmp_path / name
    p.write_text("".join(json.dumps(s) + "\n" for s in spans))
    return str(p)


def test_good_journal_passes(tmp_path):
    path = write_journal(tmp_path, [good_span(i) for i in range(8)])
    assert check_trace.run(path, expected_ok=8, out=lambda _: None) == []


def test_expected_count_mismatch_fails(tmp_path):
    path = write_journal(tmp_path, [good_span(i) for i in range(8)])
    problems = check_trace.run(path, expected_ok=10, out=lambda _: None)
    assert any("expected 10" in p for p in problems)


def test_shed_spans_do_not_count_toward_ok(tmp_path):
    spans = [good_span(i) for i in range(4)]
    spans.append(shed_span(99))
    path = write_journal(tmp_path, spans)
    assert check_trace.run(path, expected_ok=4, out=lambda _: None) == []


def test_shed_span_without_reason_fails(tmp_path):
    bad = shed_span()
    bad["reason"] = ""
    path = write_journal(tmp_path, [good_span(0), bad])
    problems = check_trace.run(path, out=lambda _: None)
    assert any("line 2" in p and "without a reason" in p for p in problems)


def test_shed_span_with_exec_window_fails(tmp_path):
    bad = shed_span()
    bad["exec_end_us"] = bad["exec_start_us"] + 500
    bad["complete_us"] = bad["exec_end_us"]
    path = write_journal(tmp_path, [bad])
    problems = check_trace.run(path, out=lambda _: None)
    assert any("exec window" in p for p in problems)


def test_shed_span_with_energy_fails(tmp_path):
    bad = shed_span()
    bad["energy_j"] = 1e-4
    path = write_journal(tmp_path, [bad])
    problems = check_trace.run(path, out=lambda _: None)
    assert any("shed span attributing energy" in p for p in problems)


def test_unknown_tenant_class_fails(tmp_path):
    bad = good_span(0, cls="platinum")
    path = write_journal(tmp_path, [bad])
    problems = check_trace.run(path, out=lambda _: None)
    assert any("unknown tenant class" in p for p in problems)


def test_pre_qos_spans_without_class_still_pass(tmp_path):
    old = good_span(0)
    del old["class"]
    del old["reason"]
    path = write_journal(tmp_path, [old])
    assert check_trace.run(path, expected_ok=1, out=lambda _: None) == []


def test_expect_total_counts_sheds(tmp_path):
    spans = [good_span(i) for i in range(3)] + [shed_span(9)]
    path = write_journal(tmp_path, spans)
    assert (
        check_trace.run(path, expect_total=4, expect_ok_min=3, expect_shed_min=1, out=lambda _: None)
        == []
    )
    problems = check_trace.run(path, expect_total=5, out=lambda _: None)
    assert any("untyped drop" in p for p in problems)


def test_expect_shed_min_detects_missing_overload(tmp_path):
    path = write_journal(tmp_path, [good_span(i) for i in range(3)])
    problems = check_trace.run(path, expect_shed_min=1, out=lambda _: None)
    assert any("did not trigger admission control" in p for p in problems)


def test_expect_ok_min_detects_collapse(tmp_path):
    path = write_journal(tmp_path, [shed_span(i) for i in range(3)])
    problems = check_trace.run(path, expect_ok_min=1, out=lambda _: None)
    assert any("stopped serving" in p for p in problems)


def telemetry_snapshot(ok=2, shed=1, per_class=None):
    pc = per_class or {
        "realtime": {"ok_spans": 1, "shed_spans": 0},
        "batch": {"ok_spans": 1, "shed_spans": 0},
        "scavenger": {"ok_spans": 0, "shed_spans": 1},
    }
    return {"trace": {"ok_spans": ok, "shed_spans": shed, "per_class": pc}}


def test_telemetry_cross_check_passes_when_consistent(tmp_path):
    spans = [good_span(0, cls="realtime"), good_span(1, cls="batch"), shed_span(2)]
    path = write_journal(tmp_path, spans)
    tpath = tmp_path / "telemetry.json"
    tpath.write_text(json.dumps(telemetry_snapshot()))
    assert check_trace.run(path, telemetry_path=str(tpath), out=lambda _: None) == []


def test_telemetry_cross_check_catches_counter_drift(tmp_path):
    spans = [good_span(0, cls="realtime"), good_span(1, cls="batch"), shed_span(2)]
    path = write_journal(tmp_path, spans)
    snap = telemetry_snapshot(ok=5)
    snap["trace"]["per_class"]["realtime"]["ok_spans"] = 4
    tpath = tmp_path / "telemetry.json"
    tpath.write_text(json.dumps(snap))
    problems = check_trace.run(path, telemetry_path=str(tpath), out=lambda _: None)
    assert any("trace.ok_spans = 5" in p for p in problems)
    assert any("per_class.realtime.ok_spans = 4" in p for p in problems)


def test_telemetry_without_trace_section_fails(tmp_path):
    path = write_journal(tmp_path, [good_span(0)])
    tpath = tmp_path / "telemetry.json"
    tpath.write_text(json.dumps({"schema": 3}))
    problems = check_trace.run(path, telemetry_path=str(tpath), out=lambda _: None)
    assert any("no trace section" in p for p in problems)


def test_main_parses_overload_flags(tmp_path, capsys):
    spans = [good_span(i) for i in range(2)] + [shed_span(9)]
    path = write_journal(tmp_path, spans)
    check_trace.main(
        [
            "check_trace.py",
            path,
            "--expect-total",
            "3",
            "--expect-ok-min",
            "2",
            "--expect-shed-min",
            "1",
        ]
    )
    assert "OK" in capsys.readouterr().out


def test_main_rejects_unknown_flag(tmp_path):
    with pytest.raises(SystemExit):
        check_trace.main(["check_trace.py", "x.jsonl", "--expect-everything", "1"])


def test_non_monotone_stamps_fail(tmp_path):
    bad = good_span()
    bad["dispatch_us"] = bad["seal_us"] - 50
    path = write_journal(tmp_path, [bad])
    problems = check_trace.run(path, expected_ok=1, out=lambda _: None)
    assert any("not monotone" in p for p in problems)


def test_missing_field_names_the_line(tmp_path):
    bad = good_span(1)
    del bad["energy_j"]
    path = write_journal(tmp_path, [good_span(0), bad])
    problems = check_trace.run(path, out=lambda _: None)
    assert any("line 2" in p and "energy_j" in p for p in problems)


def test_executed_span_without_energy_fails(tmp_path):
    bad = good_span()
    bad["energy_j"] = 0.0
    path = write_journal(tmp_path, [bad])
    problems = check_trace.run(path, out=lambda _: None)
    assert any("non-positive" in p for p in problems)


def test_unknown_outcome_fails(tmp_path):
    bad = good_span()
    bad["outcome"] = "maybe"
    path = write_journal(tmp_path, [bad])
    problems = check_trace.run(path, out=lambda _: None)
    assert any("unknown outcome" in p for p in problems)


def test_malformed_line_is_rejected_with_line_number(tmp_path):
    p = tmp_path / "trace.jsonl"
    p.write_text(json.dumps(good_span()) + "\nnot json\n")
    with pytest.raises(check_trace.TraceCheckError, match=":2"):
        check_trace.load_spans(str(p))


def test_blank_lines_are_skipped(tmp_path):
    p = tmp_path / "trace.jsonl"
    p.write_text(json.dumps(good_span()) + "\n\n" + json.dumps(good_span(1)) + "\n")
    assert len(check_trace.load_spans(str(p))) == 2


def test_empty_journal_fails(tmp_path):
    p = tmp_path / "trace.jsonl"
    p.write_text("\n")
    problems = check_trace.run(str(p), out=lambda _: None)
    assert any("no spans" in p for p in problems)


def test_missing_file_is_reported_not_raised(tmp_path):
    problems = check_trace.run(str(tmp_path / "nope.jsonl"), out=lambda _: None)
    assert len(problems) == 1 and "unreadable" in problems[0]


def test_main_exits_nonzero_on_mismatch(tmp_path, capsys):
    path = write_journal(tmp_path, [good_span(i) for i in range(3)])
    with pytest.raises(SystemExit) as e:
        check_trace.main(["check_trace.py", path, "5"])
    assert e.value.code == 1
    assert "FAIL" in capsys.readouterr().out


def test_main_passes_on_good_journal(tmp_path, capsys):
    path = write_journal(tmp_path, [good_span(i) for i in range(3)])
    check_trace.main(["check_trace.py", path, "3"])
    assert "OK" in capsys.readouterr().out
