#!/usr/bin/env python3
"""CI gate for the trace journal (trace-smoke / overload-smoke jobs).

Usage: check_trace.py <journal.jsonl> [expected_ok_spans]
                      [--expect-total N] [--expect-ok-min N]
                      [--expect-shed-min N] [--telemetry snapshot.json]

Validates the structured event journal a `fftsweep serve --trace-out`
run streams: every line must parse as JSON, carry the full span schema
(stage stamps, clock decision, occupancy, attempts, energy), keep its
stage stamps monotone in submission order (enqueue <= admit <= seal <=
dispatch <= exec_start <= exec_end <= complete), attribute a positive
energy to every executed job, and — when the expected count is given —
the journal must hold exactly that many ok spans (one per served job:
tracing that silently drops spans is an observability regression, not a
perf detail).

Shed spans (QoS admission refusals and brownout sheds) are validated
too: each must carry a non-empty `reason`, must NOT have an exec window
(exec_start_us == exec_end_us) and must attribute zero energy — a shed
that claims to have executed is a bookkeeping bug. `--expect-total`
pins the journal's total line count (every offered job terminates in a
span, ok or shed), `--expect-shed-min`/`--expect-ok-min` assert the
overload actually bit / the fleet still served, and `--telemetry` cross
checks the journal's tallies against the snapshot JSON's
`trace.ok_spans`/`trace.shed_spans` totals and `trace.per_class` split.

The checking logic lives in pure functions (`load_spans`, `check`) so
`test_check_trace.py` can unit-test pass/fail cases without spawning a
serve.
"""

import json
import sys

STAMP_KEYS = [
    "enqueue_us",
    "admit_us",
    "seal_us",
    "dispatch_us",
    "exec_start_us",
    "exec_end_us",
    "complete_us",
]
REQUIRED_KEYS = [
    "job_id",
    "artifact",
    "n",
    "card",
    *STAMP_KEYS,
    "requested_mhz",
    "granted_mhz",
    "batch_occupancy",
    "attempts",
    "energy_j",
    "outcome",
]
OUTCOMES = {"ok", "shed"}
CLASSES = ["realtime", "batch", "scavenger"]


class TraceCheckError(Exception):
    """A file-level problem (unreadable, malformed JSONL)."""


def load_spans(path):
    """Load every span from a JSONL journal; blank lines are skipped."""
    try:
        with open(path) as f:
            text = f.read()
    except OSError as e:
        raise TraceCheckError(f"{path}: unreadable ({e})")
    spans = []
    for lineno, line in enumerate(text.splitlines(), start=1):
        if not line.strip():
            continue
        try:
            span = json.loads(line)
        except ValueError as e:
            raise TraceCheckError(f"{path}:{lineno}: malformed JSON ({e})")
        if not isinstance(span, dict):
            raise TraceCheckError(f"{path}:{lineno}: span is not an object")
        spans.append((lineno, span))
    return spans


def load_telemetry(path):
    """Load a `serve --telemetry-out` snapshot for cross-checking."""
    try:
        with open(path) as f:
            return json.load(f)
    except OSError as e:
        raise TraceCheckError(f"{path}: unreadable ({e})")
    except ValueError as e:
        raise TraceCheckError(f"{path}: malformed JSON ({e})")


def check(
    spans,
    expected_ok=None,
    expect_total=None,
    expect_ok_min=None,
    expect_shed_min=None,
    telemetry=None,
):
    """Validate loaded spans; returns (problems, info) like check_bench."""
    problems = []
    ok = 0
    shed = 0
    per_class = {c: {"ok": 0, "shed": 0} for c in CLASSES}
    for lineno, span in spans:
        missing = [k for k in REQUIRED_KEYS if k not in span]
        if missing:
            problems.append(f"line {lineno}: missing span fields {missing}")
            continue
        if span["outcome"] not in OUTCOMES:
            problems.append(f"line {lineno}: unknown outcome {span['outcome']!r}")
            continue
        stamps = [span[k] for k in STAMP_KEYS]
        if any(not isinstance(s, int) or s < 0 for s in stamps):
            problems.append(f"line {lineno}: non-integer or negative stage stamp")
            continue
        if any(a > b for a, b in zip(stamps, stamps[1:])):
            problems.append(
                f"line {lineno}: stage stamps not monotone "
                f"({dict(zip(STAMP_KEYS, stamps))})"
            )
        cls = span.get("class", "")
        if cls and cls not in CLASSES:
            problems.append(f"line {lineno}: unknown tenant class {cls!r}")
            cls = ""
        if span["outcome"] == "ok":
            ok += 1
            if cls:
                per_class[cls]["ok"] += 1
            if not span["energy_j"] > 0:
                problems.append(
                    f"line {lineno}: executed span with non-positive "
                    f"energy_j {span['energy_j']}"
                )
            if not span["batch_occupancy"] >= 1:
                problems.append(
                    f"line {lineno}: executed span with occupancy "
                    f"{span['batch_occupancy']}"
                )
        else:
            shed += 1
            if cls:
                per_class[cls]["shed"] += 1
            # A shed never executed: it must say why, must not claim an
            # exec window, and must not attribute energy.
            if not span.get("reason"):
                problems.append(f"line {lineno}: shed span without a reason")
            if span["exec_start_us"] != span["exec_end_us"]:
                problems.append(
                    f"line {lineno}: shed span with an exec window "
                    f"({span['exec_start_us']}..{span['exec_end_us']})"
                )
            if span["energy_j"] != 0:
                problems.append(
                    f"line {lineno}: shed span attributing energy_j "
                    f"{span['energy_j']}"
                )
    info = [f"journal: {ok} ok span(s), {shed} shed over {len(spans)} line(s)"]
    if expected_ok is not None and ok != expected_ok:
        problems.append(
            f"journal holds {ok} ok span(s), expected {expected_ok} — "
            "tracing lost or duplicated spans"
        )
    if expect_total is not None and len(spans) != expect_total:
        problems.append(
            f"journal holds {len(spans)} span(s), expected {expect_total} — "
            "an offered job terminated without a span (untyped drop)"
        )
    if expect_ok_min is not None and ok < expect_ok_min:
        problems.append(
            f"journal holds {ok} ok span(s), need >= {expect_ok_min} — "
            "the fleet stopped serving under overload"
        )
    if expect_shed_min is not None and shed < expect_shed_min:
        problems.append(
            f"journal holds {shed} shed span(s), need >= {expect_shed_min} — "
            "overload did not trigger admission control"
        )
    if telemetry is not None:
        problems += check_telemetry(telemetry, ok, shed, per_class)
    return problems, info


def check_telemetry(snapshot, ok, shed, per_class):
    """Cross-check journal tallies against the telemetry snapshot's
    `trace` section: the spans_total counters and the per-class split
    must agree with what the journal actually holds."""
    problems = []
    tr = snapshot.get("trace")
    if not isinstance(tr, dict):
        return ["telemetry snapshot has no trace section"]
    for key, want in (("ok_spans", ok), ("shed_spans", shed)):
        got = tr.get(key)
        if got != want:
            problems.append(
                f"telemetry trace.{key} = {got}, journal holds {want} — "
                "counters and journal disagree"
            )
    pc = tr.get("per_class")
    if not isinstance(pc, dict):
        return problems + ["telemetry trace has no per_class split"]
    for cls in CLASSES:
        row = pc.get(cls)
        if not isinstance(row, dict):
            problems.append(f"telemetry trace.per_class missing class {cls!r}")
            continue
        for key, want in (("ok_spans", "ok"), ("shed_spans", "shed")):
            got = row.get(key)
            if got != per_class[cls][want]:
                problems.append(
                    f"telemetry trace.per_class.{cls}.{key} = {got}, "
                    f"journal holds {per_class[cls][want]}"
                )
    return problems


def run(
    path,
    expected_ok=None,
    expect_total=None,
    expect_ok_min=None,
    expect_shed_min=None,
    telemetry_path=None,
    out=print,
):
    """Full gate over one journal file; returns the list of problems."""
    try:
        spans = load_spans(path)
        telemetry = load_telemetry(telemetry_path) if telemetry_path else None
    except TraceCheckError as e:
        return [str(e)]
    if not spans:
        return [f"{path}: journal holds no spans"]
    problems, info = check(
        spans,
        expected_ok=expected_ok,
        expect_total=expect_total,
        expect_ok_min=expect_ok_min,
        expect_shed_min=expect_shed_min,
        telemetry=telemetry,
    )
    for line in info:
        out(line)
    return problems


def parse_args(argv):
    """Parse `<journal> [expected_ok]` plus the overload flags. Returns
    a kwargs dict for `run`, or raises SystemExit with usage."""
    usage = (
        f"usage: {argv[0]} <journal.jsonl> [expected_ok_spans] "
        "[--expect-total N] [--expect-ok-min N] [--expect-shed-min N] "
        "[--telemetry snapshot.json]"
    )
    flags = {
        "--expect-total": ("expect_total", int),
        "--expect-ok-min": ("expect_ok_min", int),
        "--expect-shed-min": ("expect_shed_min", int),
        "--telemetry": ("telemetry_path", str),
    }
    kwargs = {}
    positional = []
    args = argv[1:]
    i = 0
    while i < len(args):
        a = args[i]
        if a in flags:
            if i + 1 >= len(args):
                sys.exit(f"{a} needs a value\n{usage}")
            name, conv = flags[a]
            try:
                kwargs[name] = conv(args[i + 1])
            except ValueError:
                sys.exit(f"{a} {args[i + 1]!r}: not a number\n{usage}")
            i += 2
        elif a.startswith("--"):
            sys.exit(f"unknown flag {a}\n{usage}")
        else:
            positional.append(a)
            i += 1
    if len(positional) not in (1, 2):
        sys.exit(usage)
    kwargs["path"] = positional[0]
    if len(positional) == 2:
        try:
            kwargs["expected_ok"] = int(positional[1])
        except ValueError:
            sys.exit(f"expected_ok_spans {positional[1]!r}: not a number\n{usage}")
    return kwargs


def main(argv):
    problems = run(**parse_args(argv))
    for p in problems:
        print(f"FAIL: {p}")
    if problems:
        sys.exit(1)
    print("OK")


if __name__ == "__main__":
    main(sys.argv)
