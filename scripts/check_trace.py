#!/usr/bin/env python3
"""CI gate for the trace journal (trace-smoke job).

Usage: check_trace.py <journal.jsonl> [expected_ok_spans]

Validates the structured event journal a `fftsweep serve --trace-out`
run streams: every line must parse as JSON, carry the full span schema
(stage stamps, clock decision, occupancy, attempts, energy), keep its
stage stamps monotone in submission order (enqueue <= admit <= seal <=
dispatch <= exec_start <= exec_end <= complete), attribute a positive
energy to every executed job, and — when the expected count is given —
the journal must hold exactly that many ok spans (one per served job:
tracing that silently drops spans is an observability regression, not a
perf detail).

The checking logic lives in pure functions (`load_spans`, `check`) so
`test_check_trace.py` can unit-test pass/fail cases without spawning a
serve.
"""

import json
import sys

STAMP_KEYS = [
    "enqueue_us",
    "admit_us",
    "seal_us",
    "dispatch_us",
    "exec_start_us",
    "exec_end_us",
    "complete_us",
]
REQUIRED_KEYS = [
    "job_id",
    "artifact",
    "n",
    "card",
    *STAMP_KEYS,
    "requested_mhz",
    "granted_mhz",
    "batch_occupancy",
    "attempts",
    "energy_j",
    "outcome",
]
OUTCOMES = {"ok", "shed"}


class TraceCheckError(Exception):
    """A file-level problem (unreadable, malformed JSONL)."""


def load_spans(path):
    """Load every span from a JSONL journal; blank lines are skipped."""
    try:
        with open(path) as f:
            text = f.read()
    except OSError as e:
        raise TraceCheckError(f"{path}: unreadable ({e})")
    spans = []
    for lineno, line in enumerate(text.splitlines(), start=1):
        if not line.strip():
            continue
        try:
            span = json.loads(line)
        except ValueError as e:
            raise TraceCheckError(f"{path}:{lineno}: malformed JSON ({e})")
        if not isinstance(span, dict):
            raise TraceCheckError(f"{path}:{lineno}: span is not an object")
        spans.append((lineno, span))
    return spans


def check(spans, expected_ok=None):
    """Validate loaded spans; returns (problems, info) like check_bench."""
    problems = []
    ok = 0
    shed = 0
    for lineno, span in spans:
        missing = [k for k in REQUIRED_KEYS if k not in span]
        if missing:
            problems.append(f"line {lineno}: missing span fields {missing}")
            continue
        if span["outcome"] not in OUTCOMES:
            problems.append(f"line {lineno}: unknown outcome {span['outcome']!r}")
            continue
        stamps = [span[k] for k in STAMP_KEYS]
        if any(not isinstance(s, int) or s < 0 for s in stamps):
            problems.append(f"line {lineno}: non-integer or negative stage stamp")
            continue
        if any(a > b for a, b in zip(stamps, stamps[1:])):
            problems.append(
                f"line {lineno}: stage stamps not monotone "
                f"({dict(zip(STAMP_KEYS, stamps))})"
            )
        if span["outcome"] == "ok":
            ok += 1
            if not span["energy_j"] > 0:
                problems.append(
                    f"line {lineno}: executed span with non-positive "
                    f"energy_j {span['energy_j']}"
                )
            if not span["batch_occupancy"] >= 1:
                problems.append(
                    f"line {lineno}: executed span with occupancy "
                    f"{span['batch_occupancy']}"
                )
        else:
            shed += 1
    info = [f"journal: {ok} ok span(s), {shed} shed over {len(spans)} line(s)"]
    if expected_ok is not None and ok != expected_ok:
        problems.append(
            f"journal holds {ok} ok span(s), expected {expected_ok} — "
            "tracing lost or duplicated spans"
        )
    return problems, info


def run(path, expected_ok=None, out=print):
    """Full gate over one journal file; returns the list of problems."""
    try:
        spans = load_spans(path)
    except TraceCheckError as e:
        return [str(e)]
    if not spans:
        return [f"{path}: journal holds no spans"]
    problems, info = check(spans, expected_ok)
    for line in info:
        out(line)
    return problems


def main(argv):
    if len(argv) not in (2, 3):
        sys.exit(f"usage: {argv[0]} <journal.jsonl> [expected_ok_spans]")
    expected = int(argv[2]) if len(argv) == 3 else None
    problems = run(argv[1], expected)
    for p in problems:
        print(f"FAIL: {p}")
    if problems:
        sys.exit(1)
    print("OK")


if __name__ == "__main__":
    main(sys.argv)
